//! `leverkrr` — CLI entrypoint.
//!
//! Subcommands:
//! * `fit`        — fit a Nyström-KRR model on a dataset and report risk.
//! * `leverage`   — estimate leverage scores and dump them (JSON).
//! * `serve`      — fit then run the batched predict server: in-process
//!   demo by default, network serving with `--http` (HTTP/1.1 + JSON),
//!   artifact-store replica mode with `--replica`.
//! * `stream`     — replay a dataset as an arrival stream through the
//!   online Nyström coordinator; report accuracy-vs-time, update-latency
//!   quantiles, and the final gap to a full batch fit.
//! * `gen-data`   — write a synthetic dataset to CSV.
//! * `trace`      — run a traced fit → serve exercise, print the span
//!   summary, and dump Chrome/Perfetto trace-event JSON.
//! * `bench-fig1` / `bench-table1` / `bench-fig2` / `bench-fig3` /
//!   `bench-perf` / `bench-stream` — regenerate tables & figures.
//! * `selftest`   — quick end-to-end sanity run (native + XLA if built).
//!
//! The global `--trace` switch (any command) enables span tracing for
//! the run, equivalent to `LEVERKRR_TRACE=1`.

use leverkrr::bench_harness::{experiments, ExpOptions};
use leverkrr::coordinator::{
    fit_with_backend, spawn_replica_poller, FitConfig, HttpConfig, HttpServer, Server,
    ServerConfig,
};
use leverkrr::data::{self, Dataset};
use leverkrr::kernels::KernelSpec;
use leverkrr::leverage::{LeverageContext, LeverageMethod};
use leverkrr::runtime::Backend;
use leverkrr::util::cli::Command;
use leverkrr::util::json::Json;
use leverkrr::util::rng::Rng;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // global switch: `--trace` anywhere enables span tracing for the run
    // (same effect as LEVERKRR_TRACE=1, but wins over the environment)
    if let Some(pos) = args.iter().position(|a| a == "--trace") {
        args.remove(pos);
        leverkrr::trace::set_enabled(true);
    }
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let code = match cmd.as_str() {
        "fit" => cmd_fit(&rest),
        "run" => cmd_run_config(&rest),
        "tune" => cmd_tune(&rest),
        "leverage" => cmd_leverage(&rest),
        "serve" => cmd_serve(&rest),
        "trace" => cmd_trace(&rest),
        "stream" => cmd_stream(&rest),
        "export" => cmd_export(&rest),
        "import" => cmd_import(&rest),
        "models" => cmd_models(&rest),
        "gen-data" => cmd_gen_data(&rest),
        "bench-fig1" => {
            experiments::fig1::run(&exp_opts("bench-fig1", &rest));
            0
        }
        "bench-table1" => {
            experiments::table1::run(&exp_opts("bench-table1", &rest));
            0
        }
        "bench-fig2" => {
            experiments::fig2::run(&exp_opts("bench-fig2", &rest));
            0
        }
        "bench-fig3" => {
            experiments::fig3::run(&exp_opts("bench-fig3", &rest));
            0
        }
        "bench-perf" => {
            experiments::perf::run(&exp_opts("bench-perf", &rest));
            0
        }
        "bench-ablation" => {
            experiments::ablation::run(&exp_opts("bench-ablation", &rest));
            0
        }
        "bench-stream" => {
            experiments::stream::run(&exp_opts("bench-stream", &rest));
            0
        }
        "bench-persist" => {
            experiments::persist::run(&exp_opts("bench-persist", &rest));
            0
        }
        "bench-serve" => {
            experiments::serve::run(&exp_opts("bench-serve", &rest));
            0
        }
        "bench-obs" => {
            experiments::obs::run(&exp_opts("bench-obs", &rest));
            0
        }
        "bench-shootout" => {
            experiments::shootout::run(&experiments::shootout::ShootoutOptions::parse_argv(&rest));
            0
        }
        "selftest" => cmd_selftest(),
        "--help" | "-h" | "help" => {
            print_usage();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "leverkrr — fast statistical leverage score approximation for KRR (Chen & Yang 2021)

usage: leverkrr <command> [flags]   (each command supports --help)

commands:
  fit          fit Nyström-KRR with a chosen leverage method, report risk
  run          fit + serve from a JSON config file
  tune         cross-validated λ grid search over fixed landmarks
  leverage     estimate leverage scores, dump JSON
  serve        fit + run the dynamic-batching predict server; --http serves
               JSON over HTTP/1.1, --replica polls an artifact store and
               hot-swaps newly exported model versions
  stream       replay a dataset as an arrival stream (online Nyström);
               --warm-start resumes a persisted checkpoint
  export       fit a model and save it into the versioned artifact store
  import       load an artifact in a fresh process, verify + serve it
  models       list / garbage-collect the artifact store
  gen-data     write a synthetic dataset (CSV)
  trace        traced fit + serve exercise: span summary table on stdout,
               Chrome/Perfetto trace-event JSON to --out
  bench-fig1   Figure 1: runtime vs error trade-off (3-d bimodal)
  bench-table1 Table 1: leverage approximation accuracy (UCI-like)
  bench-fig2   Figure 2: SA vs exact rescaled leverage (1-d)
  bench-fig3   Figure 3: Gaussian kernels, growing dimension
  bench-perf   §Perf hot-path microbenches
  bench-ablation SA design-choice ablations
  bench-stream streaming update latency vs periodic full refit
  bench-persist artifact save/load/checkpoint-restore latency vs n, m
  bench-serve  HTTP-tier sustained QPS + tail latency vs batch size, replicas
  bench-obs    span-tracer overhead on the fig1 pipeline (<2% budget)
  bench-shootout time-to-equal-accuracy: exact/SA/RC/BLESS across the
               kernel zoo × input-distribution grid
  selftest     quick end-to-end sanity run

global flags:
  --trace      enable span tracing for any command (= LEVERKRR_TRACE=1)"
    );
}

/// Library-internal counters from `metrics::global()` that the fit/serve
/// paths accumulate silently: landmark-Gram-cache traffic
/// (`linalg::gramcache`) next to the KDE grid fallback count. Printed by
/// the `serve` and `stream` summaries so cache behaviour is visible
/// without a profiler.
fn print_global_counters() {
    let g = leverkrr::metrics::global();
    println!(
        "gram cache: {} hits / {} misses / {} evictions; kde grid fallbacks: {}; chol jitter retries: {}",
        g.counter("gramcache.hit"),
        g.counter("gramcache.miss"),
        g.counter("gramcache.evict"),
        g.counter("kde.grid.fallback"),
        g.counter("chol.jitter.retries"),
    );
}

fn exp_opts(name: &'static str, argv: &[String]) -> ExpOptions {
    match ExpOptions::command(name, "see module docs").parse(argv) {
        Ok(a) => ExpOptions::from_args(&a),
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Shared dataset flags → Dataset.
fn dataset_from(a: &leverkrr::util::cli::Args) -> (Dataset, Rng) {
    let seed = a.get_u64("seed").unwrap_or(0);
    let mut rng = Rng::seed_from_u64(seed);
    let n = a.get_usize("n").unwrap_or(5000);
    let ds = match a.get("data").unwrap_or("bimodal3") {
        "bimodal3" => data::bimodal3(n, 0.4, &mut rng),
        "uniform1" => data::dist1d(data::Dist1d::Uniform, n, &mut rng),
        "beta1" => data::dist1d(data::Dist1d::Beta15_2, n, &mut rng),
        "bimodal1" => data::dist1d(data::Dist1d::Bimodal, n, &mut rng),
        "rqc" | "htru2" | "ccpp" => {
            let name = data::uci::UciName::parse(a.get("data").unwrap()).unwrap();
            data::uci::load(name, "data/uci", Some(n), &mut rng)
        }
        other if other.starts_with("bimodal") => {
            let d: usize = other["bimodal".len()..].parse().expect("bimodalD");
            data::bimodal_d(n, d, 0.4, &mut rng)
        }
        other if other.starts_with("uniform") => {
            let d: usize = other["uniform".len()..].parse().expect("uniformD");
            data::shootout_dist(data::ShootoutDist::Uniform, n, d, &mut rng)
        }
        other if other.starts_with("gaussmix") => {
            let d: usize = other["gaussmix".len()..].parse().expect("gaussmixD");
            data::shootout_dist(data::ShootoutDist::GaussMix, n, d, &mut rng)
        }
        other if other.starts_with("heavytail") => {
            let d: usize = other["heavytail".len()..].parse().expect("heavytailD");
            data::shootout_dist(data::ShootoutDist::HeavyTail, n, d, &mut rng)
        }
        other if std::path::Path::new(other).exists() => {
            data::uci::load_csv(other, other).expect("csv load")
        }
        other => {
            eprintln!("unknown --data '{other}'");
            std::process::exit(2);
        }
    };
    (ds, rng)
}

fn data_flags(c: Command) -> Command {
    c.flag("data", "bimodal3", "dataset: bimodal3|uniform1|beta1|bimodal1|bimodalD|uniformD|gaussmixD|heavytailD|rqc|htru2|ccpp|<csv path>")
        .flag("n", "5000", "sample size")
        .flag("seed", "0", "RNG seed")
        .flag("kernel", "matern:nu=1.5,a=1.732", "kernel spec: matern[:nu=..,a=..] | matern12|matern32|matern52[:a=..] | laplacian[:gamma=..] | gaussian[:sigma=..] | rq[:alpha=..,ell=..]")
        .flag("lambda", "", "regularization λ (default: paper rule)")
        .flag("method", "sa", "leverage method: sa|sa-quadrature|uniform|rc|bless|exact")
        .flag("m", "", "Nyström landmarks (default: paper rule)")
        .flag("threads", "", "compute-pool workers (default: LEVERKRR_THREADS or all cores)")
        .flag("precision", "", "blocked-engine tile precision: f64|mixed (default: LEVERKRR_PRECISION or f64)")
        .switch("xla", "use AOT/PJRT backend (requires `make artifacts`)")
}

fn build_cfg(a: &leverkrr::util::cli::Args, ds: &Dataset) -> FitConfig {
    let mut cfg = FitConfig::default_for(ds);
    if let Some(k) = a.get("kernel") {
        cfg.kernel = match KernelSpec::parse(k) {
            Ok(spec) => spec,
            Err(e) => {
                eprintln!("bad --kernel: {e}");
                std::process::exit(2);
            }
        };
    }
    if let Some(l) = a.get_f64("lambda") {
        cfg.lambda = l;
    }
    if let Some(m) = a.get("method") {
        cfg.method = LeverageMethod::parse(m).expect("method");
    }
    if let Some(m) = a.get_usize("m") {
        cfg.m_sub = m;
    }
    cfg.threads = a.get_usize("threads");
    if let Some(p) = a.get("precision").filter(|s| !s.is_empty()) {
        cfg.precision =
            Some(leverkrr::linalg::blocked::Precision::parse(p).expect("precision"));
    }
    cfg.seed = a.get_u64("seed").unwrap_or(0);
    cfg
}

fn backend_from(a: &leverkrr::util::cli::Args) -> Backend {
    if a.get_bool("xla") {
        Backend::auto()
    } else {
        Backend::Native
    }
}

fn cmd_fit(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("fit", "fit Nyström-KRR and report in-sample risk"))
        .switch("tune", "cross-validate λ on a small grid before fitting (overrides --lambda)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, mut rng) = dataset_from(&a);
    let mut cfg = build_cfg(&a, &ds);
    if a.get_bool("tune") {
        let kernel = cfg.kernel.build();
        let alpha = cfg.kernel.alpha(ds.d()).min(20.0);
        let grid = leverkrr::krr::tune::lambda_grid(ds.n(), alpha, ds.d(), 7);
        let landmarks = rng.sample_without_replacement(ds.n(), cfg.m_sub.min(ds.n()));
        let res = leverkrr::krr::tune::tune_lambda(
            &kernel, &ds.x, &ds.y, &landmarks, &grid, 3, &mut rng,
        )
        .expect("tune");
        println!("tuned λ = {:.4e} (paper rule was {:.4e})", res.best_lambda, cfg.lambda);
        cfg.lambda = res.best_lambda;
    }
    let backend = backend_from(&a);
    println!(
        "fitting {} (n={}, d={}) kernel={} λ={:.3e} m={} method={:?} backend={}",
        ds.name,
        ds.n(),
        ds.d(),
        cfg.kernel.name(),
        cfg.lambda,
        cfg.m_sub,
        cfg.method,
        backend.name()
    );
    let model = fit_with_backend(&ds, &cfg, backend).expect("fit failed");
    let fitted = model.predict_batch(&ds.x);
    let risk = leverkrr::krr::in_sample_risk(&fitted, &ds.f_true);
    let train_mse = leverkrr::krr::mse(&fitted, &ds.y);
    println!("report: {}", model.report.to_json());
    println!("in-sample risk ‖f̂−f*‖²_n = {risk:.6}   train mse = {train_mse:.6}");
    let retries = leverkrr::metrics::global().counter("chol.jitter.retries");
    println!("cholesky jitter retries: {retries}");
    0
}

fn cmd_leverage(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("leverage", "estimate leverage scores, dump JSON"))
        .flag("out", "", "write scores JSON here (default stdout summary)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, mut rng) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let kernel = cfg.kernel.build();
    let est = cfg.method.build();
    let mut ctx = LeverageContext::new(&ds.x, &kernel, cfg.lambda);
    ctx.p_true = ds.p_true.as_deref();
    ctx.inner_m = cfg.inner_m;
    let _pool = cfg.threads.map(leverkrr::util::pool::override_threads);
    let (scores, secs) = leverkrr::metrics::time_it(|| est.estimate(&ctx, &mut rng));
    let q = leverkrr::leverage::normalize(&scores);
    let dstat: f64 = scores.iter().sum::<f64>() / ds.n() as f64;
    println!(
        "method={} n={} time={:.4}s  Σscores/n (≈d_stat for exact/sa) = {:.3}",
        est.name(),
        ds.n(),
        secs,
        dstat
    );
    if let Some(path) = a.get("out").filter(|s| !s.is_empty()) {
        let doc = Json::obj(vec![
            ("method", Json::Str(est.name().into())),
            ("n", Json::Num(ds.n() as f64)),
            ("secs", Json::Num(secs)),
            ("scores", Json::arr_f64(&scores)),
            ("q", Json::arr_f64(&q)),
        ]);
        std::fs::write(path, doc.to_string()).expect("write scores");
        println!("wrote {path}");
    }
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new(
        "serve",
        "fit + run the predict server (in-process demo, or HTTP with --http)",
    ))
    .flag("requests", "10000", "in-process demo: number of requests")
    .flag("max-batch", "128", "batcher max batch size")
    .flag("max-wait-ms", "2", "batcher max wait (ms)")
    .flag("http", "", "serve over HTTP on this address (e.g. 127.0.0.1:8080)")
    .flag(
        "replica",
        "",
        "artifact store dir to poll for new versions (skips fitting; requires --http)",
    )
    .flag("name", "model", "artifact name for --replica mode")
    .flag("poll-ms", "200", "replica poll interval (ms)")
    .flag(
        "duration-s",
        "",
        "HTTP mode: drain and exit after this many seconds (default: run until killed)",
    )
    .flag("queue-cap", "256", "HTTP admission queue capacity (429 beyond)")
    .flag("handlers", "", "HTTP handler threads (default: min(cores, 8))");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let scfg = ServerConfig {
        max_batch: a.get_usize("max-batch").unwrap_or(128),
        max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms").unwrap_or(2)),
        workers: leverkrr::util::pool::machine_threads().min(4),
    };
    let replica_dir = a.get("replica").filter(|s| !s.is_empty()).map(String::from);
    let http_addr = a.get("http").filter(|s| !s.is_empty()).map(String::from);
    let name = a.get("name").unwrap_or("model").to_string();
    if replica_dir.is_some() && http_addr.is_none() {
        eprintln!("--replica requires --http (a replica is a network serving process)");
        return 2;
    }

    if let Some(addr) = http_addr {
        return serve_http(&a, addr, replica_dir, &name, scfg);
    }

    let (ds, _) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let backend = backend_from(&a);
    let model =
        std::sync::Arc::new(fit_with_backend(&ds, &cfg, backend).expect("fit failed"));
    let server = Server::start(model, scfg);
    let n_req = a.get_usize("requests").unwrap_or(10_000);
    let d = ds.d();
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for w in 0..8u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(w);
                for _ in 0..n_req / 8 {
                    let q: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    std::hint::black_box(server.predict(&q));
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let reg = server.shutdown();
    println!(
        "served {} requests in {:.2}s → {:.0} req/s; mean latency {:.3} ms; {} batches (mean size {:.1})",
        reg.counter("serve.requests"),
        secs,
        reg.counter("serve.requests") as f64 / secs,
        reg.timer_mean("serve.latency.secs") * 1e3,
        reg.counter("serve.batches"),
        reg.counter("serve.requests") as f64 / reg.counter("serve.batches").max(1) as f64,
    );
    let ps = reg.timer_quantiles("serve.latency.secs", &[0.50, 0.95, 0.99]);
    println!(
        "latency quantiles: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
        ps[0] * 1e3,
        ps[1] * 1e3,
        ps[2] * 1e3,
    );
    print_global_counters();
    0
}

/// `serve --http`: network serving. Fits in-process (default) or
/// cold-starts from the latest store artifact (`--replica <dir>`, which
/// also spawns the poller that hot-swaps newly exported versions).
fn serve_http(
    a: &leverkrr::util::cli::Args,
    addr: String,
    replica_dir: Option<String>,
    name: &str,
    scfg: ServerConfig,
) -> i32 {
    let server = if let Some(dir) = &replica_dir {
        let store = match leverkrr::persist::Store::open(dir) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot open store '{dir}': {e}");
                return 1;
            }
        };
        match Server::start_from_artifact(&store, name, None, scfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot load artifact '{name}': {e}");
                return 1;
            }
        }
    } else {
        let (ds, _) = dataset_from(a);
        let cfg = build_cfg(a, &ds);
        let model = std::sync::Arc::new(
            fit_with_backend(&ds, &cfg, backend_from(a)).expect("fit failed"),
        );
        Server::start(model, scfg)
    };
    let server = std::sync::Arc::new(server);
    let mut hcfg = HttpConfig { addr, ..HttpConfig::default() };
    if let Some(q) = a.get_usize("queue-cap") {
        hcfg.queue_cap = q;
    }
    if let Some(h) = a.get_usize("handlers") {
        hcfg.handlers = h;
    }
    let http = match HttpServer::start(server.clone(), hcfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("cannot bind HTTP listener: {e}");
            return 1;
        }
    };
    println!(
        "serving HTTP on {} (model version {})",
        http.addr(),
        server.model_handle().version()
    );
    let poll_ms = a.get_u64("poll-ms").unwrap_or(200).max(1);
    let poller = replica_dir.map(|dir| {
        println!("replica mode: polling {dir} for '{name}' every {poll_ms} ms");
        spawn_replica_poller(
            std::path::PathBuf::from(dir),
            name.to_string(),
            server.model_handle(),
            server.metrics.clone(),
            std::time::Duration::from_millis(poll_ms),
        )
    });
    match a.get_f64("duration-s") {
        Some(secs) if secs > 0.0 => {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs))
        }
        _ => loop {
            // run until killed
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
    // bounded run: graceful drain, then a summary
    if let Some(p) = poller {
        p.stop();
    }
    let qps = http.qps();
    http.shutdown();
    server.stop();
    let reg = &server.metrics;
    let ps = reg.timer_quantiles("http.request.secs", &[0.50, 0.95, 0.99]);
    println!(
        "served {} http requests ({} rejected, {} bad) at {:.0} req/s; p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms; {} swaps",
        reg.counter("http.requests"),
        reg.counter("http.rejected"),
        reg.counter("http.bad_request"),
        qps,
        ps[0] * 1e3,
        ps[1] * 1e3,
        ps[2] * 1e3,
        reg.counter("replica.swaps"),
    );
    print_global_counters();
    0
}

/// `trace`: run the full pipeline — fit (leverage → landmark sampling →
/// Nyström solve) then a served predict burst — with span tracing
/// forced on, print the per-path aggregation table, and write the span
/// ring as Chrome/Perfetto trace-event JSON (load it at
/// chrome://tracing or ui.perfetto.dev).
fn cmd_trace(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new(
        "trace",
        "traced fit + serve exercise: span summary + Chrome trace JSON",
    ))
    .flag("out", "trace.json", "write Chrome/Perfetto trace-event JSON here")
    .flag("requests", "256", "served predict requests to trace");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    leverkrr::trace::set_enabled(true);
    leverkrr::trace::reset();
    let (ds, _) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let model = std::sync::Arc::new(
        fit_with_backend(&ds, &cfg, backend_from(&a)).expect("fit failed"),
    );
    // a served burst so the serving-path spans (serve.batch /
    // serve.batch.eval) land in the ring next to the fit pipeline's
    let server = Server::start(model, ServerConfig::default());
    let n_req = a.get_usize("requests").unwrap_or(256);
    let d = ds.d();
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..n_req {
        let q: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        std::hint::black_box(server.predict(&q));
    }
    server.shutdown();
    print!("{}", leverkrr::trace::summary_table());
    let out = a.get("out").unwrap_or("trace.json");
    let doc = leverkrr::trace::chrome_trace_json();
    std::fs::write(out, doc.to_string_pretty()).expect("write trace json");
    println!(
        "wrote {out} ({} spans, {} dropped)",
        leverkrr::trace::records().len(),
        leverkrr::trace::dropped()
    );
    0
}

fn cmd_stream(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new(
        "stream",
        "replay a dataset as an arrival stream (online Nyström + hot-swap publishing)",
    ))
    .flag("budget", "128", "dictionary budget (max atoms)")
    .flag("mu", "", "absolute ridge μ (default: n·λ with the paper-rule λ)")
    .flag("accept-threshold", "0.01", "dictionary admission threshold on δ/k(x,x)")
    .flag("refresh-every", "64", "publish every k arrivals (0 disables)")
    .flag("drift", "0.25", "publish on relative prequential-error drift (0 disables)")
    .flag("report-every", "", "progress row every k arrivals (default n/10)")
    .flag("warm-start", "", "restore the latest checkpoint from <dir>/<name> before replaying")
    .flag("checkpoint-dir", "", "artifact store root for periodic checkpoints")
    .flag("checkpoint-name", "stream", "artifact name checkpoints are versioned under")
    .flag("checkpoint-every", "0", "checkpoint every k arrivals (0 disables)")
    .flag("checkpoint-keep", "4", "checkpoint versions retained (0 = keep all)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let base = build_cfg(&a, &ds);
    let n = ds.n();
    let mu = a.get_f64("mu").unwrap_or(n as f64 * base.lambda);
    let budget = a.get_usize("budget").unwrap_or(128);
    let accept_threshold = a
        .get_f64("accept-threshold")
        .unwrap_or(leverkrr::stream::DEFAULT_ACCEPT_THRESHOLD);
    // validate here so bad flag values exit like any other usage error
    // instead of tripping the library asserts with a backtrace
    if mu <= 0.0 || !mu.is_finite() {
        eprintln!("--mu must be a positive number (got {mu})");
        return 2;
    }
    if budget == 0 {
        eprintln!("--budget must be at least 1");
        return 2;
    }
    if !(0.0..1.0).contains(&accept_threshold) {
        eprintln!("--accept-threshold must be in [0, 1) (got {accept_threshold})");
        return 2;
    }
    let scfg = leverkrr::stream::StreamConfig {
        kernel: base.kernel,
        mu,
        budget,
        accept_threshold,
        refresh: leverkrr::stream::RefreshPolicy {
            every: a
                .get_usize("refresh-every")
                .unwrap_or_else(|| leverkrr::stream::RefreshPolicy::default().every),
            drift: a
                .get_f64("drift")
                .unwrap_or_else(|| leverkrr::stream::RefreshPolicy::default().drift),
        },
        threads: base.threads,
        checkpoint: leverkrr::stream::CheckpointPolicy {
            every: a.get_usize("checkpoint-every").unwrap_or(0),
            dir: a
                .get("checkpoint-dir")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            name: a.get("checkpoint-name").unwrap_or("stream").to_string(),
            keep_last: a.get_usize("checkpoint-keep").unwrap_or(4),
        },
    };
    let report_every = a.get_usize("report-every").unwrap_or((n / 10).max(1));
    // identity of the stream these flags describe — stamped into every
    // checkpoint, and checked on warm start so a checkpoint is never
    // silently resumed against a different dataset
    let origin = format!(
        "{}:n={}:seed={}:d={}",
        a.get("data").unwrap_or("bimodal3"),
        n,
        a.get_u64("seed").unwrap_or(0),
        ds.d()
    );
    let mut sc = match a.get("warm-start").filter(|s| !s.is_empty()) {
        Some(spec) => {
            // resume a previous process's stream instead of starting cold;
            // the restored checkpoint carries its own config, which
            // supersedes this invocation's stream flags
            let Some((dir, name)) = spec.rsplit_once('/') else {
                eprintln!("--warm-start wants <store-dir>/<artifact-name> (got '{spec}')");
                return 2;
            };
            let store = match leverkrr::persist::Store::open(dir) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("opening artifact store '{dir}': {e}");
                    return 2;
                }
            };
            match store.load_checkpoint(name, None) {
                Ok((v, chk)) => {
                    match chk.origin.as_deref() {
                        Some(o) if o != origin => {
                            eprintln!(
                                "warm start refused: checkpoint is from stream '{o}', these flags describe '{origin}' — resuming would continue a model trained on different data"
                            );
                            return 2;
                        }
                        None => eprintln!(
                            "warning: checkpoint records no stream identity; assuming it matches '{origin}'"
                        ),
                        _ => {}
                    }
                    println!(
                        "warm start: restored '{name}' v{v} (n_seen={}, dict={})",
                        chk.model.n_seen(),
                        chk.model.m()
                    );
                    if chk.cfg.mu != scfg.mu
                        || chk.cfg.budget != scfg.budget
                        || chk.cfg.accept_threshold != scfg.accept_threshold
                        || chk.cfg.refresh != scfg.refresh
                        || chk.cfg.checkpoint != scfg.checkpoint
                    {
                        eprintln!(
                            "note: the checkpoint's config supersedes this invocation's stream flags"
                        );
                    }
                    leverkrr::stream::StreamCoordinator::restore(chk)
                }
                Err(e) => {
                    eprintln!("warm start from '{spec}' failed: {e}");
                    return 2;
                }
            }
        }
        None => leverkrr::stream::StreamCoordinator::new(scfg.clone()),
    };
    sc.set_origin(origin);
    // the *effective* config (the restored one on a warm start) — what
    // the banner and the batch-fit comparison below must describe
    let eff = sc.config().clone();
    println!(
        "streaming {} (n={}, d={}) kernel={} μ={:.3e} (λ_eq={:.3e}) budget={} refresh every {} / drift {}",
        ds.name,
        n,
        ds.d(),
        eff.kernel.name(),
        eff.mu,
        eff.mu / n as f64,
        eff.budget,
        eff.refresh.every,
        eff.refresh.drift,
    );
    let report = leverkrr::stream::replay_into(&mut sc, &ds, report_every);
    println!("\n  arrivals  dict  rolling_rmse  version  elapsed_s");
    for r in &report.rows {
        println!(
            "  {:>8}  {:>4}  {:>12.5}  {:>7}  {:>9.3}",
            r.arrivals, r.dict, r.rolling_rmse, r.version, r.elapsed_secs
        );
    }
    // end-state accuracy vs a full batch fit at the equivalent λ = μ/n
    // and the same landmark capacity (m = budget), so the printed gap
    // measures the streaming approximation, not a capacity mismatch
    let snap = sc.model().snapshot();
    let stream_risk =
        leverkrr::krr::in_sample_risk(&snap.predict_batch(&ds.x), &ds.f_true);
    let mut bcfg = base.clone();
    bcfg.lambda = eff.mu / n as f64;
    bcfg.m_sub = eff.budget.min(n);
    let batch = fit_with_backend(&ds, &bcfg, Backend::Native).expect("batch fit");
    let batch_risk =
        leverkrr::krr::in_sample_risk(&batch.predict_batch(&ds.x), &ds.f_true);
    let (s_rmse, b_rmse) = (stream_risk.sqrt(), batch_risk.sqrt());
    println!(
        "\nreplayed {} of {} arrivals in {:.3}s  (dict {}/{}, {} publishes, final version {})",
        report.ingested,
        n,
        report.total_secs,
        report.dict,
        eff.budget,
        sc.metrics.counter("stream.publishes"),
        report.final_version,
    );
    if report.ingested > 0 {
        println!(
            "update latency: p50 {:.1} µs  p95 {:.1} µs  p99 {:.1} µs",
            report.update_p50 * 1e6,
            report.update_p95 * 1e6,
            report.update_p99 * 1e6,
        );
    } else {
        println!("no new arrivals: the checkpoint already covers this stream");
    }
    println!(
        "end-state RMSE: stream {:.5} vs batch (m={}) {:.5}  ({:+.2}%)",
        s_rmse,
        bcfg.m_sub,
        b_rmse,
        100.0 * (s_rmse - b_rmse) / b_rmse.max(1e-12),
    );
    print_global_counters();
    0
}

/// Deterministic probe document: 64 query points + the exporter's
/// predictions. `import --probe` re-predicts in a fresh process and
/// compares bit patterns (JSON `f64` text round-trips exactly: Rust's
/// shortest-representation formatter ↔ `str::parse`).
fn make_probe(model: &leverkrr::coordinator::FittedModel, d: usize) -> Json {
    let k = 64usize;
    let mut rng = Rng::seed_from_u64(0xBEEF);
    let xq = leverkrr::linalg::Mat::from_fn(k, d, |_, _| rng.f64());
    let preds = model.predict_batch(&xq);
    Json::obj(vec![
        ("d", Json::Num(d as f64)),
        ("k", Json::Num(k as f64)),
        ("xs", Json::arr_f64(&xq.data)),
        ("preds", Json::arr_f64(&preds)),
    ])
}

fn cmd_export(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new(
        "export",
        "fit a model and save it into the versioned artifact store",
    ))
    .flag("dir", "models", "artifact store root directory")
    .flag("name", "model", "artifact name (versions increment automatically)")
    .flag("gc-keep", "0", "after saving, keep only the newest k versions (0 = keep all)")
    .flag("probe-out", "", "write a probe JSON (query points + predictions) for `import --probe`");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let backend = backend_from(&a);
    let model = fit_with_backend(&ds, &cfg, backend).expect("fit failed");
    let store = leverkrr::persist::Store::open(a.get("dir").unwrap()).expect("open store");
    let name = a.get("name").unwrap();
    let meta = match model.save(&store, name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("export failed: {e}");
            return 1;
        }
    };
    println!(
        "exported {} v{} ({} bytes): kernel {}, n={}, m={}, d={}",
        store.path_of(name, meta.version).display(),
        meta.version,
        meta.bytes,
        meta.kernel,
        meta.n,
        meta.m,
        meta.d,
    );
    if let Some(path) = a.get("probe-out").filter(|s| !s.is_empty()) {
        let probe = make_probe(&model, ds.d());
        std::fs::write(path, probe.to_string_pretty()).expect("write probe");
        println!("wrote probe {path} (64 points)");
    }
    let keep = a.get_usize("gc-keep").unwrap_or(0);
    if keep > 0 {
        let removed = store.gc(name, keep).expect("gc");
        if removed > 0 {
            println!("gc: removed {removed} old version(s), kept newest {keep}");
        }
    }
    0
}

fn cmd_import(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "import",
        "load an artifact in a fresh process, verify it, and serve it",
    )
    .flag("dir", "models", "artifact store root directory")
    .flag_req("name", "artifact name")
    .flag("version", "", "version to load (default: latest)")
    .flag("probe", "", "probe JSON from `export --probe-out`: verify bitwise via direct + served predictions");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let store = leverkrr::persist::Store::open(a.get("dir").unwrap()).expect("open store");
    let name = a.get("name").unwrap();
    let version = a.get_u64("version");
    let (v, model) = match store.load_model(name, version) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("import failed: {e}");
            return 1;
        }
    };
    println!(
        "imported {name} v{v}: kernel {}, m={}, d={}, λ={:.3e}",
        model.nystrom.kernel.spec.name(),
        model.nystrom.m(),
        model.nystrom.landmarks.cols,
        model.nystrom.lambda,
    );
    let Some(path) = a.get("probe").filter(|s| !s.is_empty()) else {
        return 0;
    };
    let text = std::fs::read_to_string(path).expect("read probe");
    let doc = Json::parse(&text).expect("probe json");
    let d = doc.get("d").as_usize().expect("probe d");
    let k = doc.get("k").as_usize().expect("probe k");
    let take_f64s = |key: &str| -> Vec<f64> {
        doc.get(key)
            .as_arr()
            .expect("probe array")
            .iter()
            .map(|v| v.as_f64().expect("probe number"))
            .collect()
    };
    let xs = take_f64s("xs");
    let want = take_f64s("preds");
    assert_eq!(xs.len(), k * d, "probe xs arity");
    assert_eq!(want.len(), k, "probe preds arity");
    let xq = leverkrr::linalg::Mat { rows: k, cols: d, data: xs };
    // 1) direct predict in this process
    let direct = model.predict_batch(&xq);
    let bad_direct =
        direct.iter().zip(&want).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
    // 2) the cold-start serving path: artifact → ModelHandle → batched server
    let server = leverkrr::coordinator::Server::start_from_artifact(
        &store,
        name,
        version,
        ServerConfig::default(),
    )
    .expect("start_from_artifact");
    let mut bad_served = 0;
    for i in 0..k {
        let p = server.try_predict(xq.row(i)).expect("serve probe");
        if p.value.to_bits() != want[i].to_bits() {
            bad_served += 1;
        }
    }
    server.shutdown();
    if bad_direct == 0 && bad_served == 0 {
        println!("probe OK: {k}/{k} predictions bit-identical (direct + served), zero refit work");
        0
    } else {
        eprintln!(
            "probe FAILED: {bad_direct}/{k} direct and {bad_served}/{k} served predictions deviate from the exporter"
        );
        1
    }
}

fn cmd_models(argv: &[String]) -> i32 {
    let cmd = Command::new("models", "list / garbage-collect the artifact store")
        .flag("dir", "models", "artifact store root directory")
        .flag("name", "", "restrict to one artifact name")
        .flag("gc-keep", "0", "keep only the newest k versions of --name (0 = list only)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let store = leverkrr::persist::Store::open(a.get("dir").unwrap()).expect("open store");
    let name = a.get("name").filter(|s| !s.is_empty());
    let keep = a.get_usize("gc-keep").unwrap_or(0);
    if keep > 0 {
        let Some(n) = name else {
            eprintln!("--gc-keep needs --name");
            return 2;
        };
        let removed = store.gc(n, keep).expect("gc");
        println!("gc '{n}': removed {removed} version(s), kept newest {keep}");
    }
    let entries = match name {
        Some(n) => store.list_name(n),
        None => store.list(),
    };
    if entries.is_empty() {
        println!("no artifacts under {}", store.root().display());
        return 0;
    }
    let mut t = leverkrr::bench_harness::Table::new(&[
        "name", "version", "kind", "created_unix", "n", "m", "d", "kernel", "bytes",
    ]);
    for e in &entries {
        t.row(vec![
            e.name.clone(),
            e.version.to_string(),
            e.kind.clone(),
            e.created_unix.to_string(),
            e.n.to_string(),
            e.m.to_string(),
            e.d.to_string(),
            e.kernel.clone(),
            e.bytes.to_string(),
        ]);
    }
    t.print();
    0
}

fn cmd_gen_data(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("gen-data", "write a synthetic dataset to CSV"))
        .flag_req("out", "output CSV path");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let mut s = String::new();
    for i in 0..ds.n() {
        for j in 0..ds.d() {
            s.push_str(&format!("{},", ds.x[(i, j)]));
        }
        s.push_str(&format!("{}\n", ds.y[i]));
    }
    let path = a.get("out").unwrap();
    std::fs::write(path, s).expect("write csv");
    println!("wrote {} rows to {path}", ds.n());
    0
}

fn cmd_run_config(argv: &[String]) -> i32 {
    let cmd = Command::new("run", "fit + serve from a JSON config file")
        .flag_req("config", "path to the JSON config")
        .switch("xla", "use AOT/PJRT backend");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let rc = leverkrr::coordinator::RunConfig::from_file(a.get("config").unwrap())
        .expect("config");
    let ds = rc.build_dataset().expect("dataset");
    if rc.stream_serve {
        // ingest + serve in one process through the stream coordinator
        return run_stream_serve(&rc, &ds);
    }
    let cfg = rc.fit_config(&ds);
    let backend = backend_from(&a);
    println!(
        "run: {} n={} method={:?} λ={:.3e} m={} backend={}",
        ds.name, ds.n(), cfg.method, cfg.lambda, cfg.m_sub, backend.name()
    );
    let model = fit_with_backend(&ds, &cfg, backend).expect("fit");
    let risk = leverkrr::krr::in_sample_risk(&model.predict_batch(&ds.x), &ds.f_true);
    println!("report: {}  risk={risk:.6}", model.report.to_json());
    persist_model_if_configured(&rc, &model);
    0
}

/// Export the run's model into the configured artifact store (no-op
/// when the `persist` section is absent).
fn persist_model_if_configured(
    rc: &leverkrr::coordinator::RunConfig,
    model: &leverkrr::coordinator::FittedModel,
) {
    let Some(dir) = &rc.persist.dir else { return };
    let store = leverkrr::persist::Store::open(dir).expect("open artifact store");
    let meta = model.save(&store, &rc.persist.name).expect("export model");
    println!(
        "persisted model '{}' v{} ({} bytes) under {}",
        meta.name,
        meta.version,
        meta.bytes,
        store.root().display()
    );
    if rc.persist.keep_last > 0 {
        let removed = store.gc(&rc.persist.name, rc.persist.keep_last).expect("gc");
        if removed > 0 {
            println!("gc: removed {removed} old version(s)");
        }
    }
}

/// `run` with `stream.serve = true`: the stream coordinator ingests the
/// dataset as live arrivals while the hot-swap server answers queries
/// from the same process — with the `persist` section set, the run
/// warm-starts from the latest checkpoint, checkpoints periodically
/// while ingesting, and exports the final model + checkpoint on exit
/// (so the next run resumes instead of refitting).
fn run_stream_serve(rc: &leverkrr::coordinator::RunConfig, ds: &Dataset) -> i32 {
    let scfg = rc.stream_config(ds);
    // identity of the stream this config describes — stamped into every
    // checkpoint; a checkpoint from a *different* dataset must not be
    // resumed (n_seen would offset into the new stream and the run would
    // serve a model trained on the old data as a "continuation")
    let origin =
        format!("{}:n={}:seed={}:d={}", rc.data_name, rc.n, rc.seed, ds.d());
    let mut sc = None;
    if let (Some(dir), true) = (&rc.persist.dir, rc.persist.warm_start) {
        let store = leverkrr::persist::Store::open(dir).expect("open artifact store");
        let ckpt_name = rc.persist.checkpoint_name();
        if store.latest(&ckpt_name).is_some() {
            match store.load_checkpoint(&ckpt_name, None) {
                Ok((v, chk)) => {
                    let chk_origin = chk.origin.clone();
                    if let Some(o) = chk_origin.as_deref().filter(|o| *o != origin) {
                        eprintln!(
                            "warm start skipped: checkpoint '{ckpt_name}' v{v} is from stream '{o}', this config describes '{origin}'; starting cold"
                        );
                    } else {
                        if chk_origin.is_none() {
                            eprintln!(
                                "warning: checkpoint records no stream identity; assuming it matches '{origin}'"
                            );
                        }
                        println!(
                            "warm start: checkpoint '{ckpt_name}' v{v} (n_seen={}, dict={})",
                            chk.model.n_seen(),
                            chk.model.m()
                        );
                        sc = Some(leverkrr::stream::StreamCoordinator::restore(chk));
                    }
                }
                Err(e) => eprintln!("warm start skipped ({e}); starting cold"),
            }
        }
    }
    let mut sc =
        sc.unwrap_or_else(|| leverkrr::stream::StreamCoordinator::new(scfg.clone()));
    sc.set_origin(origin);
    // the *effective* config: on a warm start the restored checkpoint's
    // config governs, superseding the document's stream/checkpoint knobs
    let eff = sc.config().clone();
    if eff.budget != scfg.budget
        || eff.mu != scfg.mu
        || eff.refresh != scfg.refresh
        || eff.checkpoint != scfg.checkpoint
    {
        eprintln!(
            "note: the restored checkpoint's config supersedes the document's stream settings"
        );
    }
    let handle = sc.handle();
    let server = Server::start_with_handle(handle, rc.serve.clone());
    let (n, d) = (ds.n(), ds.d());
    // treat the dataset as the full stream history: a warm-started
    // coordinator resumes at its own position instead of re-ingesting
    // (and double-weighting) arrivals it already absorbed
    let start = (sc.n_seen() as usize).min(n);
    println!(
        "run (stream-serve): arrivals {start}..{n} into budget {} (refresh every {} / drift {}), serving concurrently",
        eff.budget, eff.refresh.every, eff.refresh.drift
    );
    let t0 = std::time::Instant::now();
    let sc = std::thread::scope(|s| {
        let server = &server;
        let ingester = s.spawn(move || {
            for i in start..n {
                sc.ingest(ds.x.row(i), ds.y[i]);
            }
            sc.publish_now();
            sc
        });
        // demo query traffic riding alongside ingestion (hot swaps land
        // at batch boundaries; requests in flight finish on their snapshot)
        for w in 0..2u64 {
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(w);
                for _ in 0..1000 {
                    let q: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    let _ = server.try_predict(&q);
                }
            });
        }
        ingester.join().expect("ingest thread")
    });
    let secs = t0.elapsed().as_secs_f64();
    let reg = server.shutdown();
    let snap = sc.model().snapshot();
    let risk = leverkrr::krr::in_sample_risk(&snap.predict_batch(&ds.x), &ds.f_true);
    let ps = reg.timer_quantiles("serve.latency.secs", &[0.50, 0.95]);
    println!(
        "ingested {} arrivals in {:.2}s (dict {}/{}, {} publishes, {} checkpoints); served {} requests (p50 {:.3} ms, p95 {:.3} ms); in-sample risk {:.6}",
        sc.n_seen(),
        secs,
        sc.dict_len(),
        eff.budget,
        sc.metrics.counter("stream.publishes"),
        sc.metrics.counter("stream.checkpoints"),
        reg.counter("serve.requests"),
        ps[0] * 1e3,
        ps[1] * 1e3,
        risk,
    );
    print_global_counters();
    // model export + gc shares the batch path's helper; only the final
    // checkpoint (for the next warm start) is stream-specific
    persist_model_if_configured(rc, &snap);
    if let Some(dir) = &rc.persist.dir {
        let store = leverkrr::persist::Store::open(dir).expect("open artifact store");
        let ckpt_name = rc.persist.checkpoint_name();
        let cmeta =
            store.save_checkpoint(&ckpt_name, &sc.checkpoint()).expect("export checkpoint");
        println!("persisted checkpoint '{ckpt_name}' v{}", cmeta.version);
        if rc.persist.keep_last > 0 {
            let removed = store.gc(&ckpt_name, rc.persist.keep_last).expect("gc");
            if removed > 0 {
                println!("gc: removed {removed} old checkpoint(s)");
            }
        }
    }
    0
}

fn cmd_tune(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("tune", "cross-validated λ grid search"))
        .flag("folds", "5", "CV folds")
        .flag("grid", "9", "λ grid points");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, mut rng) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let kernel = cfg.kernel.build();
    let alpha = cfg.kernel.alpha(ds.d()).min(20.0);
    let grid = leverkrr::krr::tune::lambda_grid(
        ds.n(),
        alpha,
        ds.d(),
        a.get_usize("grid").unwrap_or(9),
    );
    let landmarks = rng.sample_without_replacement(ds.n(), cfg.m_sub.min(ds.n()));
    let res = leverkrr::krr::tune::tune_lambda(
        &kernel,
        &ds.x,
        &ds.y,
        &landmarks,
        &grid,
        a.get_usize("folds").unwrap_or(5),
        &mut rng,
    )
    .expect("tune");
    println!("λ grid (λ, cv mse):");
    for (l, m) in &res.path {
        let marker = if *l == res.best_lambda { "  <-- best" } else { "" };
        println!("  {l:.4e}  {m:.6}{marker}");
    }
    println!("paper-rule λ would be {:.4e}", cfg.lambda);
    0
}

fn cmd_selftest() -> i32 {
    let mut rng = Rng::seed_from_u64(0);
    let ds = data::bimodal3(3000, 0.4, &mut rng);
    let cfg = FitConfig::default_for(&ds);
    // native
    let m = fit_with_backend(&ds, &cfg, Backend::Native).expect("native fit");
    let risk = leverkrr::krr::in_sample_risk(&m.predict_batch(&ds.x), &ds.f_true);
    println!("native: risk={risk:.5} report={}", m.report.to_json());
    // xla if available
    match leverkrr::runtime::Engine::load_default() {
        Ok(engine) => {
            let backend = Backend::Xla(std::sync::Arc::new(engine));
            let m2 = fit_with_backend(&ds, &cfg, backend).expect("xla fit");
            let risk2 = leverkrr::krr::in_sample_risk(&m2.predict_batch(&ds.x), &ds.f_true);
            println!("xla:    risk={risk2:.5} report={}", m2.report.to_json());
            let dev = (risk - risk2).abs() / risk.max(1e-12);
            println!("risk deviation native↔xla: {dev:.2e}");
            if dev > 0.05 {
                eprintln!("FAIL: backends disagree");
                return 1;
            }
        }
        Err(e) => println!("xla engine unavailable ({e}); native-only selftest"),
    }
    println!("selftest OK");
    0
}
