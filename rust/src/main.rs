//! `leverkrr` — CLI entrypoint.
//!
//! Subcommands:
//! * `fit`        — fit a Nyström-KRR model on a dataset and report risk.
//! * `leverage`   — estimate leverage scores and dump them (JSON).
//! * `serve`      — fit then run the batched predict server demo.
//! * `stream`     — replay a dataset as an arrival stream through the
//!   online Nyström coordinator; report accuracy-vs-time, update-latency
//!   quantiles, and the final gap to a full batch fit.
//! * `gen-data`   — write a synthetic dataset to CSV.
//! * `bench-fig1` / `bench-table1` / `bench-fig2` / `bench-fig3` /
//!   `bench-perf` / `bench-stream` — regenerate tables & figures.
//! * `selftest`   — quick end-to-end sanity run (native + XLA if built).

use leverkrr::bench_harness::{experiments, ExpOptions};
use leverkrr::coordinator::{fit_with_backend, FitConfig, Server, ServerConfig};
use leverkrr::data::{self, Dataset};
use leverkrr::kernels::KernelSpec;
use leverkrr::leverage::{LeverageContext, LeverageMethod};
use leverkrr::runtime::Backend;
use leverkrr::util::cli::Command;
use leverkrr::util::json::Json;
use leverkrr::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let code = match cmd.as_str() {
        "fit" => cmd_fit(&rest),
        "run" => cmd_run_config(&rest),
        "tune" => cmd_tune(&rest),
        "leverage" => cmd_leverage(&rest),
        "serve" => cmd_serve(&rest),
        "stream" => cmd_stream(&rest),
        "gen-data" => cmd_gen_data(&rest),
        "bench-fig1" => {
            experiments::fig1::run(&exp_opts("bench-fig1", &rest));
            0
        }
        "bench-table1" => {
            experiments::table1::run(&exp_opts("bench-table1", &rest));
            0
        }
        "bench-fig2" => {
            experiments::fig2::run(&exp_opts("bench-fig2", &rest));
            0
        }
        "bench-fig3" => {
            experiments::fig3::run(&exp_opts("bench-fig3", &rest));
            0
        }
        "bench-perf" => {
            experiments::perf::run(&exp_opts("bench-perf", &rest));
            0
        }
        "bench-ablation" => {
            experiments::ablation::run(&exp_opts("bench-ablation", &rest));
            0
        }
        "bench-stream" => {
            experiments::stream::run(&exp_opts("bench-stream", &rest));
            0
        }
        "selftest" => cmd_selftest(),
        "--help" | "-h" | "help" => {
            print_usage();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "leverkrr — fast statistical leverage score approximation for KRR (Chen & Yang 2021)

usage: leverkrr <command> [flags]   (each command supports --help)

commands:
  fit          fit Nyström-KRR with a chosen leverage method, report risk
  run          fit + serve from a JSON config file
  tune         cross-validated λ grid search over fixed landmarks
  leverage     estimate leverage scores, dump JSON
  serve        fit + run the dynamic-batching predict server demo
  stream       replay a dataset as an arrival stream (online Nyström)
  gen-data     write a synthetic dataset (CSV)
  bench-fig1   Figure 1: runtime vs error trade-off (3-d bimodal)
  bench-table1 Table 1: leverage approximation accuracy (UCI-like)
  bench-fig2   Figure 2: SA vs exact rescaled leverage (1-d)
  bench-fig3   Figure 3: Gaussian kernels, growing dimension
  bench-perf   §Perf hot-path microbenches
  bench-ablation SA design-choice ablations
  bench-stream streaming update latency vs periodic full refit
  selftest     quick end-to-end sanity run"
    );
}

fn exp_opts(name: &'static str, argv: &[String]) -> ExpOptions {
    match ExpOptions::command(name, "see module docs").parse(argv) {
        Ok(a) => ExpOptions::from_args(&a),
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Shared dataset flags → Dataset.
fn dataset_from(a: &leverkrr::util::cli::Args) -> (Dataset, Rng) {
    let seed = a.get_u64("seed").unwrap_or(0);
    let mut rng = Rng::seed_from_u64(seed);
    let n = a.get_usize("n").unwrap_or(5000);
    let ds = match a.get("data").unwrap_or("bimodal3") {
        "bimodal3" => data::bimodal3(n, 0.4, &mut rng),
        "uniform1" => data::dist1d(data::Dist1d::Uniform, n, &mut rng),
        "beta1" => data::dist1d(data::Dist1d::Beta15_2, n, &mut rng),
        "bimodal1" => data::dist1d(data::Dist1d::Bimodal, n, &mut rng),
        "rqc" | "htru2" | "ccpp" => {
            let name = data::uci::UciName::parse(a.get("data").unwrap()).unwrap();
            data::uci::load(name, "data/uci", Some(n), &mut rng)
        }
        other if other.starts_with("bimodal") => {
            let d: usize = other["bimodal".len()..].parse().expect("bimodalD");
            data::bimodal_d(n, d, 0.4, &mut rng)
        }
        other if std::path::Path::new(other).exists() => {
            data::uci::load_csv(other, other).expect("csv load")
        }
        other => {
            eprintln!("unknown --data '{other}'");
            std::process::exit(2);
        }
    };
    (ds, rng)
}

fn data_flags(c: Command) -> Command {
    c.flag("data", "bimodal3", "dataset: bimodal3|uniform1|beta1|bimodal1|bimodalD|rqc|htru2|ccpp|<csv path>")
        .flag("n", "5000", "sample size")
        .flag("seed", "0", "RNG seed")
        .flag("kernel", "matern:nu=1.5,a=1.732", "kernel spec (matern:nu=..,a=.. | gaussian:sigma=..)")
        .flag("lambda", "", "regularization λ (default: paper rule)")
        .flag("method", "sa", "leverage method: sa|sa-quadrature|uniform|rc|bless|exact")
        .flag("m", "", "Nyström landmarks (default: paper rule)")
        .flag("threads", "", "compute-pool workers (default: LEVERKRR_THREADS or all cores)")
        .switch("xla", "use AOT/PJRT backend (requires `make artifacts`)")
}

fn build_cfg(a: &leverkrr::util::cli::Args, ds: &Dataset) -> FitConfig {
    let mut cfg = FitConfig::default_for(ds);
    if let Some(k) = a.get("kernel") {
        cfg.kernel = KernelSpec::parse(k).expect("kernel spec");
    }
    if let Some(l) = a.get_f64("lambda") {
        cfg.lambda = l;
    }
    if let Some(m) = a.get("method") {
        cfg.method = LeverageMethod::parse(m).expect("method");
    }
    if let Some(m) = a.get_usize("m") {
        cfg.m_sub = m;
    }
    cfg.threads = a.get_usize("threads");
    cfg.seed = a.get_u64("seed").unwrap_or(0);
    cfg
}

fn backend_from(a: &leverkrr::util::cli::Args) -> Backend {
    if a.get_bool("xla") {
        Backend::auto()
    } else {
        Backend::Native
    }
}

fn cmd_fit(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("fit", "fit Nyström-KRR and report in-sample risk"));
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let backend = backend_from(&a);
    println!(
        "fitting {} (n={}, d={}) kernel={} λ={:.3e} m={} method={:?} backend={}",
        ds.name,
        ds.n(),
        ds.d(),
        cfg.kernel.name(),
        cfg.lambda,
        cfg.m_sub,
        cfg.method,
        backend.name()
    );
    let model = fit_with_backend(&ds, &cfg, backend).expect("fit failed");
    let fitted = model.predict_batch(&ds.x);
    let risk = leverkrr::krr::in_sample_risk(&fitted, &ds.f_true);
    let train_mse = leverkrr::krr::mse(&fitted, &ds.y);
    println!("report: {}", model.report.to_json());
    println!("in-sample risk ‖f̂−f*‖²_n = {risk:.6}   train mse = {train_mse:.6}");
    0
}

fn cmd_leverage(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("leverage", "estimate leverage scores, dump JSON"))
        .flag("out", "", "write scores JSON here (default stdout summary)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, mut rng) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let kernel = cfg.kernel.build();
    let est = cfg.method.build();
    let mut ctx = LeverageContext::new(&ds.x, &kernel, cfg.lambda);
    ctx.p_true = ds.p_true.as_deref();
    ctx.inner_m = cfg.inner_m;
    let _pool = cfg.threads.map(leverkrr::util::pool::override_threads);
    let (scores, secs) = leverkrr::metrics::time_it(|| est.estimate(&ctx, &mut rng));
    let q = leverkrr::leverage::normalize(&scores);
    let dstat: f64 = scores.iter().sum::<f64>() / ds.n() as f64;
    println!(
        "method={} n={} time={:.4}s  Σscores/n (≈d_stat for exact/sa) = {:.3}",
        est.name(),
        ds.n(),
        secs,
        dstat
    );
    if let Some(path) = a.get("out").filter(|s| !s.is_empty()) {
        let doc = Json::obj(vec![
            ("method", Json::Str(est.name().into())),
            ("n", Json::Num(ds.n() as f64)),
            ("secs", Json::Num(secs)),
            ("scores", Json::arr_f64(&scores)),
            ("q", Json::arr_f64(&q)),
        ]);
        std::fs::write(path, doc.to_string()).expect("write scores");
        println!("wrote {path}");
    }
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("serve", "fit + run the predict server demo"))
        .flag("requests", "10000", "number of demo requests")
        .flag("max-batch", "128", "batcher max batch size")
        .flag("max-wait-ms", "2", "batcher max wait (ms)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let backend = backend_from(&a);
    let model =
        std::sync::Arc::new(fit_with_backend(&ds, &cfg, backend).expect("fit failed"));
    let scfg = ServerConfig {
        max_batch: a.get_usize("max-batch").unwrap_or(128),
        max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms").unwrap_or(2)),
        workers: leverkrr::util::pool::machine_threads().min(4),
    };
    let server = Server::start(model, scfg);
    let n_req = a.get_usize("requests").unwrap_or(10_000);
    let d = ds.d();
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for w in 0..8u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(w);
                for _ in 0..n_req / 8 {
                    let q: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    std::hint::black_box(server.predict(&q));
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let reg = server.shutdown();
    println!(
        "served {} requests in {:.2}s → {:.0} req/s; mean latency {:.3} ms; {} batches (mean size {:.1})",
        reg.counter("serve.requests"),
        secs,
        reg.counter("serve.requests") as f64 / secs,
        reg.timer_mean("serve.latency.secs") * 1e3,
        reg.counter("serve.batches"),
        reg.counter("serve.requests") as f64 / reg.counter("serve.batches").max(1) as f64,
    );
    let ps = reg.timer_quantiles("serve.latency.secs", &[0.50, 0.95, 0.99]);
    println!(
        "latency quantiles: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
        ps[0] * 1e3,
        ps[1] * 1e3,
        ps[2] * 1e3,
    );
    0
}

fn cmd_stream(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new(
        "stream",
        "replay a dataset as an arrival stream (online Nyström + hot-swap publishing)",
    ))
    .flag("budget", "128", "dictionary budget (max atoms)")
    .flag("mu", "", "absolute ridge μ (default: n·λ with the paper-rule λ)")
    .flag("accept-threshold", "0.01", "dictionary admission threshold on δ/k(x,x)")
    .flag("refresh-every", "64", "publish every k arrivals (0 disables)")
    .flag("drift", "0.25", "publish on relative prequential-error drift (0 disables)")
    .flag("report-every", "", "progress row every k arrivals (default n/10)");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let base = build_cfg(&a, &ds);
    let n = ds.n();
    let mu = a.get_f64("mu").unwrap_or(n as f64 * base.lambda);
    let budget = a.get_usize("budget").unwrap_or(128);
    let accept_threshold = a
        .get_f64("accept-threshold")
        .unwrap_or(leverkrr::stream::DEFAULT_ACCEPT_THRESHOLD);
    // validate here so bad flag values exit like any other usage error
    // instead of tripping the library asserts with a backtrace
    if mu <= 0.0 || !mu.is_finite() {
        eprintln!("--mu must be a positive number (got {mu})");
        return 2;
    }
    if budget == 0 {
        eprintln!("--budget must be at least 1");
        return 2;
    }
    if !(0.0..1.0).contains(&accept_threshold) {
        eprintln!("--accept-threshold must be in [0, 1) (got {accept_threshold})");
        return 2;
    }
    let scfg = leverkrr::stream::StreamConfig {
        kernel: base.kernel,
        mu,
        budget,
        accept_threshold,
        refresh: leverkrr::stream::RefreshPolicy {
            every: a
                .get_usize("refresh-every")
                .unwrap_or_else(|| leverkrr::stream::RefreshPolicy::default().every),
            drift: a
                .get_f64("drift")
                .unwrap_or_else(|| leverkrr::stream::RefreshPolicy::default().drift),
        },
        threads: base.threads,
    };
    println!(
        "streaming {} (n={}, d={}) kernel={} μ={:.3e} (λ_eq={:.3e}) budget={} refresh every {} / drift {}",
        ds.name,
        n,
        ds.d(),
        scfg.kernel.name(),
        scfg.mu,
        scfg.mu / n as f64,
        scfg.budget,
        scfg.refresh.every,
        scfg.refresh.drift,
    );
    let report_every = a.get_usize("report-every").unwrap_or((n / 10).max(1));
    let (sc, report) = leverkrr::stream::replay(&ds, &scfg, report_every);
    println!("\n  arrivals  dict  rolling_rmse  version  elapsed_s");
    for r in &report.rows {
        println!(
            "  {:>8}  {:>4}  {:>12.5}  {:>7}  {:>9.3}",
            r.arrivals, r.dict, r.rolling_rmse, r.version, r.elapsed_secs
        );
    }
    // end-state accuracy vs a full batch fit at the equivalent λ = μ/n
    // and the same landmark capacity (m = budget), so the printed gap
    // measures the streaming approximation, not a capacity mismatch
    let snap = sc.model().snapshot();
    let stream_risk =
        leverkrr::krr::in_sample_risk(&snap.predict_batch(&ds.x), &ds.f_true);
    let mut bcfg = base.clone();
    bcfg.lambda = mu / n as f64;
    bcfg.m_sub = scfg.budget.min(n);
    let batch = fit_with_backend(&ds, &bcfg, Backend::Native).expect("batch fit");
    let batch_risk =
        leverkrr::krr::in_sample_risk(&batch.predict_batch(&ds.x), &ds.f_true);
    let (s_rmse, b_rmse) = (stream_risk.sqrt(), batch_risk.sqrt());
    println!(
        "\nreplayed {} arrivals in {:.3}s  (dict {}/{}, {} publishes, final version {})",
        n,
        report.total_secs,
        report.dict,
        scfg.budget,
        sc.metrics.counter("stream.publishes"),
        report.final_version,
    );
    println!(
        "update latency: p50 {:.1} µs  p95 {:.1} µs  p99 {:.1} µs",
        report.update_p50 * 1e6,
        report.update_p95 * 1e6,
        report.update_p99 * 1e6,
    );
    println!(
        "end-state RMSE: stream {:.5} vs batch (m={}) {:.5}  ({:+.2}%)",
        s_rmse,
        bcfg.m_sub,
        b_rmse,
        100.0 * (s_rmse - b_rmse) / b_rmse.max(1e-12),
    );
    0
}

fn cmd_gen_data(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("gen-data", "write a synthetic dataset to CSV"))
        .flag_req("out", "output CSV path");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, _) = dataset_from(&a);
    let mut s = String::new();
    for i in 0..ds.n() {
        for j in 0..ds.d() {
            s.push_str(&format!("{},", ds.x[(i, j)]));
        }
        s.push_str(&format!("{}\n", ds.y[i]));
    }
    let path = a.get("out").unwrap();
    std::fs::write(path, s).expect("write csv");
    println!("wrote {} rows to {path}", ds.n());
    0
}

fn cmd_run_config(argv: &[String]) -> i32 {
    let cmd = Command::new("run", "fit + serve from a JSON config file")
        .flag_req("config", "path to the JSON config")
        .switch("xla", "use AOT/PJRT backend");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let rc = leverkrr::coordinator::RunConfig::from_file(a.get("config").unwrap())
        .expect("config");
    let ds = rc.build_dataset().expect("dataset");
    let cfg = rc.fit_config(&ds);
    let backend = backend_from(&a);
    println!(
        "run: {} n={} method={:?} λ={:.3e} m={} backend={}",
        ds.name, ds.n(), cfg.method, cfg.lambda, cfg.m_sub, backend.name()
    );
    let model = fit_with_backend(&ds, &cfg, backend).expect("fit");
    let risk = leverkrr::krr::in_sample_risk(&model.predict_batch(&ds.x), &ds.f_true);
    println!("report: {}  risk={risk:.6}", model.report.to_json());
    0
}

fn cmd_tune(argv: &[String]) -> i32 {
    let cmd = data_flags(Command::new("tune", "cross-validated λ grid search"))
        .flag("folds", "5", "CV folds")
        .flag("grid", "9", "λ grid points");
    let a = match cmd.parse(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let (ds, mut rng) = dataset_from(&a);
    let cfg = build_cfg(&a, &ds);
    let kernel = cfg.kernel.build();
    let alpha = cfg.kernel.alpha(ds.d()).min(20.0);
    let grid = leverkrr::krr::tune::lambda_grid(
        ds.n(),
        alpha,
        ds.d(),
        a.get_usize("grid").unwrap_or(9),
    );
    let landmarks = rng.sample_without_replacement(ds.n(), cfg.m_sub.min(ds.n()));
    let res = leverkrr::krr::tune::tune_lambda(
        &kernel,
        &ds.x,
        &ds.y,
        &landmarks,
        &grid,
        a.get_usize("folds").unwrap_or(5),
        &mut rng,
    )
    .expect("tune");
    println!("λ grid (λ, cv mse):");
    for (l, m) in &res.path {
        let marker = if *l == res.best_lambda { "  <-- best" } else { "" };
        println!("  {l:.4e}  {m:.6}{marker}");
    }
    println!("paper-rule λ would be {:.4e}", cfg.lambda);
    0
}

fn cmd_selftest() -> i32 {
    let mut rng = Rng::seed_from_u64(0);
    let ds = data::bimodal3(3000, 0.4, &mut rng);
    let cfg = FitConfig::default_for(&ds);
    // native
    let m = fit_with_backend(&ds, &cfg, Backend::Native).expect("native fit");
    let risk = leverkrr::krr::in_sample_risk(&m.predict_batch(&ds.x), &ds.f_true);
    println!("native: risk={risk:.5} report={}", m.report.to_json());
    // xla if available
    match leverkrr::runtime::Engine::load_default() {
        Ok(engine) => {
            let backend = Backend::Xla(std::sync::Arc::new(engine));
            let m2 = fit_with_backend(&ds, &cfg, backend).expect("xla fit");
            let risk2 = leverkrr::krr::in_sample_risk(&m2.predict_batch(&ds.x), &ds.f_true);
            println!("xla:    risk={risk2:.5} report={}", m2.report.to_json());
            let dev = (risk - risk2).abs() / risk.max(1e-12);
            println!("risk deviation native↔xla: {dev:.2e}");
            if dev > 0.05 {
                eprintln!("FAIL: backends disagree");
                return 1;
            }
        }
        Err(e) => println!("xla engine unavailable ({e}); native-only selftest"),
    }
    println!("selftest OK");
    0
}
