//! BLESS — Bottom-up Leverage Score Sampling (Rudi et al., 2018).
//!
//! Path-following over a geometric regularization schedule
//! λ_0 = κ² (= 1 for our normalized kernels) down to the target λ:
//! at each step h the candidate pool is a uniform subsample of size
//! ∝ min(n, c/λ_h) (the BLESS insight: accurate RLS at level λ_h only
//! needs that many points), the candidates are scored against the
//! previous dictionary via [`super::rls::dictionary_rls`], and a new
//! dictionary of the configured size is resampled proportionally to the
//! scores. A final pass scores all n points with the converged
//! dictionary (Table 1 / Figure 1 compare *all* leverage scores, so
//! every method pays this O(n·m²) output step).

use super::rls::dictionary_rls_in;
use super::{LeverageContext, LeverageEstimator};
use crate::linalg::GramCache;
use crate::trace;
use crate::util::rng::{AliasTable, Rng};

#[derive(Clone, Debug)]
pub struct Bless {
    /// Geometric step: λ_{h+1} = λ_h / step (paper uses q ≈ 2).
    pub step: f64,
    /// Candidate-pool constant: |U_h| = min(n, pool_coef / λ_h).
    pub pool_coef: f64,
}

impl Default for Bless {
    fn default() -> Self {
        Bless { step: 2.0, pool_coef: 2.0 }
    }
}

impl LeverageEstimator for Bless {
    fn name(&self) -> &'static str {
        "bless"
    }

    fn estimate(&self, ctx: &LeverageContext, rng: &mut Rng) -> Vec<f64> {
        let _span = trace::span("leverage.bless");
        match ctx.cache {
            Some(shared) => self.run(ctx, &mut shared.borrow_mut(), rng),
            None => {
                // private caching workspace: columns survive across the
                // path-following levels (bit-identical to a shared one)
                let mut ws = GramCache::new(ctx.kernel.clone(), ctx.x);
                self.run(ctx, &mut ws, rng)
            }
        }
    }
}

impl Bless {
    /// The path-following loop against a shared landmark Gram workspace:
    /// each level's scoring pass installs its dictionary into the
    /// workspace, so a landmark resampled at the next level (common —
    /// high-leverage points persist along the λ path) is a cache hit
    /// instead of a fresh K_·J column, and the final all-points output
    /// pass reuses the converged dictionary's columns outright.
    fn run(&self, ctx: &LeverageContext, ws: &mut GramCache, rng: &mut Rng) -> Vec<f64> {
        assert!(
            std::ptr::eq(ws.points(), ctx.x),
            "shared Gram workspace must be keyed to the context's point set"
        );
        let n = ctx.n();
        let m_dict = ctx.inner_m.max(4);
        // Initial dictionary: small uniform sample at λ_0 = 1 (κ² = k(x,x)).
        let mut dict = rng.sample_without_replacement(n, m_dict.min(n));
        let mut lam_h = 1.0_f64;
        let target = ctx.lambda;
        while lam_h > target {
            lam_h = (lam_h / self.step).max(target);
            // candidate pool: uniform subsample of size min(n, c/λ_h)
            let pool_size = ((self.pool_coef / lam_h) as usize).clamp(m_dict, n);
            let pool = if pool_size >= n {
                (0..n).collect::<Vec<_>>()
            } else {
                rng.sample_without_replacement(n, pool_size)
            };
            // score candidates at level λ_h with the previous dictionary
            let scores = dictionary_rls_in(ws, lam_h, &dict, Some(&pool));
            // resample the dictionary ∝ scores
            let at = AliasTable::new(&scores);
            let mut new_dict: Vec<usize> =
                (0..m_dict).map(|_| pool[at.sample(rng)]).collect();
            new_dict.sort_unstable();
            new_dict.dedup();
            dict = new_dict;
        }
        // output pass: score everything at the target λ
        dictionary_rls_in(ws, target, &dict, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{dist1d, Dist1d};
    use crate::kernels::{Kernel, KernelSpec};
    use crate::leverage::exact::rescaled_leverage_exact;
    use crate::leverage::LeverageContext;

    #[test]
    fn bless_correlates_with_exact() {
        let mut rng = Rng::seed_from_u64(1);
        let n = 400;
        let ds = dist1d(Dist1d::Bimodal, n, &mut rng);
        let nu = 1.5;
        let k = Kernel::new(KernelSpec::Matern { nu, a: (2.0 * nu).sqrt() });
        let lam = crate::krr::lambda::fig2(n);
        let exact = rescaled_leverage_exact(&ds.x, &k, lam);
        let ctx = LeverageContext { x: &ds.x, kernel: &k, lambda: lam, p_true: None, inner_m: 40, cache: None };
        let est = Bless::default().estimate(&ctx, &mut rng);
        assert_eq!(est.len(), n);
        let qe = crate::leverage::normalize(&exact);
        let qa = crate::leverage::normalize(&est);
        let mut ratios: Vec<f64> = (0..n).map(|i| qa[i] / qe[i]).collect();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = ratios[ratios.len() / 2];
        assert!((med - 1.0).abs() < 0.35, "median ratio {med}");
    }

    #[test]
    fn bless_handles_tiny_problems() {
        let mut rng = Rng::seed_from_u64(2);
        let ds = dist1d(Dist1d::Uniform, 25, &mut rng);
        let k = Kernel::new(KernelSpec::Matern { nu: 0.5, a: 1.0 });
        let ctx = LeverageContext { x: &ds.x, kernel: &k, lambda: 1e-3, p_true: None, inner_m: 8, cache: None };
        let s = Bless::default().estimate(&ctx, &mut rng);
        assert!(s.iter().all(|&v| v > 0.0 && v.is_finite()));
    }

    #[test]
    fn bless_deterministic_given_seed() {
        let mk = || {
            let mut rng = Rng::seed_from_u64(3);
            let ds = dist1d(Dist1d::Uniform, 150, &mut rng);
            let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
            let ctx =
                LeverageContext { x: &ds.x, kernel: &k, lambda: 1e-3, p_true: None, inner_m: 20, cache: None };
            let mut r2 = Rng::seed_from_u64(99);
            Bless::default().estimate(&ctx, &mut r2)
        };
        assert_eq!(mk(), mk());
    }
}
