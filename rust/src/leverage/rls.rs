//! Dictionary-based approximate ridge leverage scores (RLS) and the
//! Recursive-RLS estimator of Musco & Musco (2017).
//!
//! Core primitive: given a landmark dictionary J (|J| = m), approximate
//! G_λ(x_i,x_i) by replacing K_n with its Nyström approximation
//! L = K_nJ K_JJ^† K_Jn. With B = K_nJ R^{-1} (K_JJ = RᵀR, jittered) the
//! push-through identity gives
//!
//!   [L(L + nλI)^{−1}]_ii = b_iᵀ (BᵀB + nλ I_m)^{−1} b_i,
//!
//! computable for all n points in O(n·m² + m³) after the O(n·m·d) kernel
//! block. This is the inner step of both Recursive-RLS and BLESS.
//!
//! Recursive-RLS (Musco & Musco 2017, Algorithm 3, adapted): recursively
//! halve the data; at each level, use the child's dictionary to score the
//! current points, then resample a dictionary of the configured size
//! proportionally to the scores. The final dictionary scores all n
//! points. We keep the unweighted-dictionary Nyström RLS (the
//! Alaoui–Mahoney form) rather than the weighted variant — same
//! complexity and accuracy class; noted in DESIGN.md.

use super::{LeverageContext, LeverageEstimator};
use crate::kernels::Kernel;
use crate::linalg::{Cholesky, GramCache, Mat};
use crate::trace;
use crate::util::rng::{AliasTable, Rng};

/// Approximate rescaled leverage scores of the rows of `x` using landmark
/// rows `dict` (indices into `x`). Returns G-hat (scaled by n like the
/// exact scores).
///
/// One-shot form: builds a throwaway reference-mode workspace, so the
/// cost and the bits are exactly the pre-workspace path's. Repeated
/// callers (the recursion in [`RecursiveRls`], BLESS's path following)
/// should hold a [`GramCache`] and call [`dictionary_rls_in`] so landmark
/// columns shared between calls are evaluated only once.
pub fn dictionary_rls(
    x: &Mat,
    kernel: &Kernel,
    lambda: f64,
    dict: &[usize],
    subset: Option<&[usize]>,
) -> Vec<f64> {
    let mut ws = GramCache::new_uncached(kernel.clone(), x);
    dictionary_rls_in(&mut ws, lambda, dict, subset)
}

/// [`dictionary_rls`] against a shared landmark Gram workspace: installs
/// `dict` into the workspace (extending or rebuilding K_JJ and its
/// factor as needed) and assembles K_{rows,J} from cached columns —
/// every landmark column is evaluated at most once per workspace
/// lifetime. Scores are bit-identical to the one-shot form whenever the
/// workspace *rebuilds* for `dict` (any non-prefix transition — the case
/// every current recursive consumer hits, since per-level dictionaries
/// are resampled rather than grown). When `dict` strictly extends the
/// workspace's current list, the K_JJ factor is extended by
/// [`crate::linalg::Cholesky::append_row`], whose low-order rounding
/// (and jitter placement) legitimately differs from a from-scratch
/// factorization — results then still satisfy both parity contracts
/// (cached ≡ uncached and thread-count invariance; see
/// [`crate::linalg::gramcache`]) but are not bitwise the one-shot form.
pub fn dictionary_rls_in(
    ws: &mut GramCache,
    lambda: f64,
    dict: &[usize],
    subset: Option<&[usize]>,
) -> Vec<f64> {
    let n = ws.points().rows;
    let m = dict.len();
    assert!(m > 0, "empty dictionary");
    let nlam = n as f64 * lambda;
    // K_JJ = R Rᵀ (lower L here) — factored (with jitter) by the
    // workspace; K_{rows,J} gathered/assembled by it in one shot, then
    // B rows b_i = L^{-1} k_{J,i} (pool-parallel; each b_i is an
    // independent triangular solve).
    ws.set_landmarks(dict);
    let kxj = ws.block(subset);
    let chol_jj = ws.factor();
    let chunks = crate::util::pool::par_chunks(kxj.rows, |range| {
        let mut bs = Vec::with_capacity(range.len());
        for r in range {
            let mut k_col = kxj.row(r).to_vec();
            chol_jj.solve_lower_in_place(&mut k_col);
            bs.push(k_col);
        }
        bs
    });
    let b_rows: Vec<Vec<f64>> = chunks.into_iter().flatten().collect();
    // kxj is dead once the solves are done — release the n×m block before
    // the O(n·m²) accumulation below doubles the peak footprint.
    drop(kxj);
    // M = BᵀB + nλ I_m  (note: BᵀB over the *scored subset*; when scoring
    // a subset we still want the geometry of those points only — this is
    // the standard subset-Nyström RLS used inside the recursions).
    let mut mmat = Mat::zeros(m, m);
    for b in &b_rows {
        for a in 0..m {
            let ba = b[a];
            if ba == 0.0 {
                continue;
            }
            for c in a..m {
                mmat[(a, c)] += ba * b[c];
            }
        }
    }
    for a in 0..m {
        for c in 0..a {
            mmat[(a, c)] = mmat[(c, a)];
        }
    }
    mmat.add_diag(nlam);
    let chol_m = Cholesky::factor_jittered(&mmat).expect("M PD");
    // score_i = n · b_iᵀ M^{−1} b_i  (∈ (0, n))
    let out = crate::util::pool::par_chunks(b_rows.len(), |range| {
        range
            .map(|r| {
                let q = chol_m.quad_form(&b_rows[r]);
                (n as f64 * q).clamp(1e-12, n as f64)
            })
            .collect::<Vec<_>>()
    });
    out.into_iter().flatten().collect()
}

/// Musco & Musco (2017) Recursive-RLS.
#[derive(Clone, Debug)]
pub struct RecursiveRls {
    /// Oversampling multiplier on the dictionary size at each level.
    pub oversample: f64,
}

impl Default for RecursiveRls {
    fn default() -> Self {
        RecursiveRls { oversample: 1.0 }
    }
}

impl RecursiveRls {
    /// Returns the dictionary built over `active` (indices into the
    /// workspace's point set). Every level scores through the shared
    /// workspace, so a landmark column evaluated at one level is a cache
    /// hit at every later level that resamples the same point.
    fn build_dictionary(
        &self,
        lambda: f64,
        ws: &mut GramCache,
        active: &[usize],
        m_dict: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        if active.len() <= (2 * m_dict).max(16) {
            return active.to_vec();
        }
        // random half
        let half: Vec<usize> = active.iter().copied().filter(|_| rng.f64() < 0.5).collect();
        let half = if half.is_empty() { vec![active[0]] } else { half };
        let child = self.build_dictionary(lambda, ws, &half, m_dict, rng);
        // score the active set with the child dictionary
        let scores = dictionary_rls_in(ws, lambda, &child, Some(active));
        // resample dictionary ∝ scores
        let at = AliasTable::new(&scores);
        let take = ((m_dict as f64 * self.oversample).round() as usize).max(4);
        let mut dict: Vec<usize> = (0..take).map(|_| active[at.sample(rng)]).collect();
        dict.sort_unstable();
        dict.dedup();
        dict
    }

    fn run(&self, ctx: &LeverageContext, ws: &mut GramCache, rng: &mut Rng) -> Vec<f64> {
        assert!(
            std::ptr::eq(ws.points(), ctx.x),
            "shared Gram workspace must be keyed to the context's point set"
        );
        let all: Vec<usize> = (0..ctx.n()).collect();
        let dict = self.build_dictionary(ctx.lambda, ws, &all, ctx.inner_m, rng);
        dictionary_rls_in(ws, ctx.lambda, &dict, None)
    }
}

impl LeverageEstimator for RecursiveRls {
    fn name(&self) -> &'static str {
        "recursive-rls"
    }

    fn estimate(&self, ctx: &LeverageContext, rng: &mut Rng) -> Vec<f64> {
        let _span = trace::span("leverage.rls");
        match ctx.cache {
            Some(shared) => self.run(ctx, &mut shared.borrow_mut(), rng),
            None => {
                // private caching workspace: the recursion still reuses
                // columns level-to-level, bit-identically to a shared one
                let mut ws = GramCache::new(ctx.kernel.clone(), ctx.x);
                self.run(ctx, &mut ws, rng)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{dist1d, Dist1d};
    use crate::kernels::KernelSpec;
    use crate::leverage::exact::rescaled_leverage_exact;

    fn setup(n: usize, seed: u64) -> (crate::data::Dataset, Kernel, f64) {
        let mut rng = Rng::seed_from_u64(seed);
        let ds = dist1d(Dist1d::Bimodal, n, &mut rng);
        let nu = 1.5;
        let k = Kernel::new(KernelSpec::Matern { nu, a: (2.0 * nu).sqrt() });
        let lam = crate::krr::lambda::fig2(n);
        (ds, k, lam)
    }

    #[test]
    fn full_dictionary_recovers_exact() {
        // dict = all points ⇒ L = K ⇒ scores = exact G.
        let (ds, k, lam) = setup(90, 1);
        let exact = rescaled_leverage_exact(&ds.x, &k, lam);
        let all: Vec<usize> = (0..ds.n()).collect();
        let approx = dictionary_rls(&ds.x, &k, lam, &all, None);
        for i in 0..ds.n() {
            assert!(
                (approx[i] - exact[i]).abs() < 1e-5 * exact[i].max(1.0),
                "i={i}: {} vs {}",
                approx[i],
                exact[i]
            );
        }
    }

    #[test]
    fn dictionary_rls_underestimates() {
        // Nyström approximation L ⪯ K ⇒ approx scores ≤ exact (up to
        // jitter noise) — the classic one-sided bound.
        let (ds, k, lam) = setup(150, 2);
        let exact = rescaled_leverage_exact(&ds.x, &k, lam);
        let mut rng = Rng::seed_from_u64(7);
        let dict = rng.sample_without_replacement(ds.n(), 40);
        let approx = dictionary_rls(&ds.x, &k, lam, &dict, None);
        let violations = (0..ds.n())
            .filter(|&i| approx[i] > exact[i] * 1.05 + 1e-6)
            .count();
        assert!(
            violations < ds.n() / 20,
            "{violations}/{} points exceed the exact score",
            ds.n()
        );
    }

    #[test]
    fn recursive_rls_correlates_with_exact() {
        let (ds, k, lam) = setup(400, 3);
        let exact = rescaled_leverage_exact(&ds.x, &k, lam);
        let mut rng = Rng::seed_from_u64(11);
        let ctx = LeverageContext {
            x: &ds.x,
            kernel: &k,
            lambda: lam,
            p_true: None,
            inner_m: 40,
            cache: None,
        };
        let est = RecursiveRls::default().estimate(&ctx, &mut rng);
        // normalized scores should be close: mean ratio ~1
        let qe = crate::leverage::normalize(&exact);
        let qa = crate::leverage::normalize(&est);
        let mut ratios: Vec<f64> = (0..ds.n()).map(|i| qa[i] / qe[i]).collect();
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = ratios[ratios.len() / 2];
        assert!((med - 1.0).abs() < 0.35, "median ratio {med}");
    }

    #[test]
    fn shared_workspace_matches_one_shot_bitwise() {
        // dictionary_rls_in against a warm caching workspace must equal
        // the one-shot (reference-mode) dictionary_rls bit for bit —
        // gathered columns, K_JJ, and factor all agree by construction.
        let (ds, k, lam) = setup(140, 6);
        let mut rng = Rng::seed_from_u64(8);
        let dict_a = rng.sample_without_replacement(ds.n(), 25);
        let dict_b = rng.sample_without_replacement(ds.n(), 30);
        let subset: Vec<usize> = (0..70).map(|i| i * 2).collect();
        let mut ws = crate::linalg::GramCache::new(k.clone(), &ds.x);
        for dict in [&dict_a, &dict_b, &dict_a] {
            let cached = dictionary_rls_in(&mut ws, lam, dict, Some(&subset));
            let oneshot = dictionary_rls(&ds.x, &k, lam, dict, Some(&subset));
            assert_eq!(cached, oneshot, "cached-vs-one-shot diverged");
        }
        assert!(ws.stats().hits > 0, "revisited dictionaries must hit the cache");
    }

    #[test]
    fn subset_scoring_matches_full_on_those_rows() {
        let (ds, k, lam) = setup(120, 4);
        let mut rng = Rng::seed_from_u64(5);
        let dict = rng.sample_without_replacement(ds.n(), 30);
        let subset: Vec<usize> = (0..ds.n()).collect();
        let full = dictionary_rls(&ds.x, &k, lam, &dict, None);
        let sub = dictionary_rls(&ds.x, &k, lam, &dict, Some(&subset));
        for i in 0..ds.n() {
            assert!((full[i] - sub[i]).abs() < 1e-9);
        }
    }
}
