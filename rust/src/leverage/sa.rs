//! SA — the paper's spectral-analysis leverage score approximation.
//!
//! For a stationary kernel with spectral density m(s) and input density
//! p, the rescaled leverage score G_λ(x_i, x_i) is approximated by
//!
//!   K̃_λ(x_i, x_i) = ∫_{R^d} ds / ( p(x_i) + λ / m(s) )          (Eqn 6)
//!
//! Pipeline (Algorithm 1): estimate p̂(x_i) by fast KDE, evaluate the
//! integral per point, normalize. Total Õ(n).
//!
//! Integral evaluation (Appendix D):
//! * **Polar reduction**: isotropy ⇒ Eqn 6 = ω_{d−1}·∫₀^∞ r^{d−1}/(p +
//!   λ/m(r)) dr, a 1-d integral ([`SaIntegration::Quadrature`]).
//! * **Matérn closed form** (App. D.2): dropping the +a² spectral shift
//!   (o(1) relative error as λ→0) gives
//!   K̃ ≈ ω_{d−1}/(2π)^d · Γ-form · p^{d/(2α)−1} (λ/C_m)^{−d/(2α)},
//!   the paper's p^{d/(2α)−1} rule of thumb with exact constants so the
//!   value overlays the true G in Figure 2.
//! * **Gaussian closed form**: K̃ = −Li_{d/2}(−y)/(p·c), y = p·c/λ,
//!   c = (2πσ²)^{d/2}, via the polylogarithm in [`crate::special`].
//! * **Laplacian**: the Matérn ν=½ power law with a = γ — shares the
//!   Matérn closed form exactly.
//! * **Rational-quadratic**: its Bessel-form spectral density
//!   (see [`crate::kernels::SpectralDensity`]) has no elementary
//!   antiderivative, so RQ always takes the polar-reduced quadrature
//!   route, even under [`SaIntegration::ClosedForm`].
//!
//! We use the kernels' true spectral constants (not the paper's C_α=D_α=1
//! simplification) so K̃ matches G in absolute scale, which Figure 2
//! requires.
//!
//! The KDE stage (the only pairwise-quadratic part of Algorithm 1) runs
//! on the blocked distance engine — see [`crate::kde`] and
//! [`crate::linalg::blocked`]; the per-point quadrature stays a
//! per-element pool map.
//!
//! SA itself evaluates no K_·J landmark blocks — that is its selling
//! point — so a shared [`crate::linalg::GramCache`] on the context is
//! passed through untouched here. In the fit pipeline the same workspace
//! is handed to the Nyström stage afterwards, which assembles *its*
//! landmark blocks through it; for the algebraic estimators (RC/BLESS)
//! those columns are then partly pre-paid, while for SA the workspace
//! simply starts cold (`rust/tests/gramcache_parity.rs` pins that an
//! attached workspace never perturbs SA's scores).

use super::{LeverageContext, LeverageEstimator};
use crate::kde::{self, KdeMethod};
use crate::kernels::{Kernel, KernelSpec};
use crate::quadrature::{integrate_semi_infinite_panels, GaussLegendre};
use crate::special::{polylog_neg, sphere_surface};
use crate::trace;
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// How to evaluate the Eqn-6 integral.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SaIntegration {
    /// Analytic forms (Matérn power law / Gaussian polylog). Default.
    ClosedForm,
    /// Polar-reduced 1-d numerical quadrature (validation path, also the
    /// route for kernels without a closed form).
    Quadrature,
}

/// The SA estimator with its tuning knobs.
#[derive(Clone, Debug)]
pub struct SaEstimator {
    pub kde: KdeMethod,
    /// KDE bandwidth; None → Scott's rule (benches pass the paper's).
    pub bandwidth: Option<f64>,
    pub integration: SaIntegration,
    /// Use the generator's true density if the context provides it
    /// (isolates formula error from KDE error in tests/Figure 2).
    pub use_true_density: bool,
    /// §B.3 low-density stabilization: p < h₀ ⇒ p ← (0.5h₀ + p)/1.5 with
    /// h₀ = `stab_coef`·n^{−0.8}.
    pub stabilize: bool,
    pub stab_coef: f64,
    /// Leave-one-out KDE correction (see [`crate::kde::loo_correct`]):
    /// removes the self-term that otherwise flattens the density profile
    /// at small bandwidths in moderate d. On by default.
    pub loo: bool,
}

impl Default for SaEstimator {
    fn default() -> Self {
        SaEstimator {
            kde: KdeMethod::Auto,
            bandwidth: None,
            integration: SaIntegration::ClosedForm,
            use_true_density: false,
            stabilize: true,
            stab_coef: 0.3,
            loo: true,
        }
    }
}

// The spectral-density descriptions (exact constants for the full
// kernel zoo) live with the kernels; re-exported here because SA is
// their primary consumer and the historical home of the type.
pub use crate::kernels::SpectralDensity;

/// Evaluate K̃_λ(x,x) for a single density value p — closed form.
///
/// Matérn and Laplacian use the power-law integral (App. D.2); the
/// Gaussian uses the polylog. The rational-quadratic density has no
/// elementary antiderivative, so its "closed form" is the polar-reduced
/// quadrature with a locally-built rule — batch callers
/// ([`SaEstimator::scores_from_density`]) route RQ through the shared
/// pool-parallel quadrature path instead of calling this per point.
pub fn sa_value_closed_form(p: f64, sd: &SpectralDensity, lambda: f64) -> f64 {
    let d = sd.d as f64;
    match sd.spec {
        KernelSpec::Matern { .. } | KernelSpec::Laplacian { .. } => {
            let alpha = sd.alpha;
            // ∫ r^{d−1}/(p + B r^{2α}) dr with B = λ(2π)^{2α}/C_m, then
            // × ω_{d−1}:  value = ω_{d−1} p^{d/2α−1} B^{−d/2α} (π/2α)/sin(πd/2α)
            let b = lambda * (2.0 * PI).powf(2.0 * alpha) / sd.matern_cm;
            let s = PI / (2.0 * alpha) / (PI * d / (2.0 * alpha)).sin();
            sphere_surface(sd.d) * p.powf(d / (2.0 * alpha) - 1.0) * b.powf(-d / (2.0 * alpha))
                * s
        }
        KernelSpec::Gaussian { sigma } => {
            // K̃ = −Li_{d/2}(−y)/(p c), y = p c / λ, c = (2πσ²)^{d/2}
            let c = (2.0 * PI * sigma * sigma).powf(d / 2.0);
            let y = p * c / lambda;
            -polylog_neg(d / 2.0, y) / (p * c)
        }
        KernelSpec::RationalQuadratic { .. } => {
            sa_value_quadrature(p, sd, lambda, &GaussLegendre::new(32))
        }
    }
}

/// Evaluate K̃_λ(x,x) by polar-reduced quadrature (Appendix D.1):
/// ω_{d−1} ∫₀^∞ r^{d−1}/(p + λ/m(r)) dr.
pub fn sa_value_quadrature(
    p: f64,
    sd: &SpectralDensity,
    lambda: f64,
    gl: &GaussLegendre,
) -> f64 {
    let d = sd.d as f64;
    // characteristic radius where λ/m(r) ≈ p — center the panels there
    let r0 = match sd.spec {
        KernelSpec::Matern { a, .. } | KernelSpec::Laplacian { gamma: a } => {
            let t = (p * sd.matern_cm / lambda).powf(1.0 / (2.0 * sd.alpha));
            ((t - a * a).max(1.0)).sqrt() / (2.0 * PI)
        }
        KernelSpec::Gaussian { sigma } => {
            let c = (2.0 * PI * sigma * sigma).powf(d / 2.0);
            let y = (p * c / lambda).max(2.0);
            (y.ln()).sqrt() / (PI * sigma * 2.0f64.sqrt()) + 1.0
        }
        KernelSpec::RationalQuadratic { .. } => {
            // m decays like e^{−t}, t = rq_as·r: λ/m overtakes p near
            // t ≈ ln(p·m(0)/λ).
            let y = (p * sd.m0 / lambda).max(2.0);
            y.ln().max(1.0) / sd.rq_as
        }
    };
    let f = |r: f64| {
        let m = sd.eval(r);
        if m <= 0.0 {
            return 0.0;
        }
        r.powf(d - 1.0) / (p + lambda / m)
    };
    sphere_surface(sd.d) * integrate_semi_infinite_panels(gl, r0.max(1e-6), &f, 1e-10, 120)
}

/// Apply §B.3 stabilization to a density estimate.
pub fn stabilize_density(p: f64, n: usize, coef: f64) -> f64 {
    let h0 = coef * (n as f64).powf(-0.8);
    if p < h0 {
        (0.5 * h0 + p) / 1.5
    } else {
        p
    }
}

/// Table-driven polylog for the Gaussian closed form.
///
/// One SA estimate needs Li_{d/2}(−y_i) at n different y_i — each a
/// (cheap but not free) Fermi–Dirac quadrature. F(u) = ln(−Li_s(−e^u))
/// is smooth and monotone, so 256 knots of linear interpolation over the
/// observed ln-y range give ~1e-5 relative error at O(1) per point,
/// turning the Gaussian SA pass from O(n·quad) into O(n) (§Perf: 42s →
/// sub-second at n=10⁴, d=10).
struct PolylogTable {
    s: f64,
    lo: f64,
    hi: f64,
    step: f64,
    /// F(u) = ln(−Li_s(−e^u)) at the knots.
    f: Vec<f64>,
}

impl PolylogTable {
    fn new(s: f64, y_min: f64, y_max: f64) -> PolylogTable {
        let lo = y_min.max(1e-290).ln() - 1e-9;
        let hi = y_max.max(y_min.max(1e-290) * (1.0 + 1e-9)).ln() + 1e-9;
        let knots = 256usize;
        let step = (hi - lo) / (knots - 1) as f64;
        let f = (0..knots)
            .map(|i| {
                let y = (lo + i as f64 * step).exp();
                (-polylog_neg(s, y)).max(1e-300).ln()
            })
            .collect();
        PolylogTable { s, lo, hi, step, f }
    }

    /// −Li_s(−y) via interpolation (falls back to direct evaluation
    /// outside the table range).
    fn neg_li(&self, y: f64) -> f64 {
        let u = y.max(1e-290).ln();
        if u < self.lo || u > self.hi {
            return -polylog_neg(self.s, y);
        }
        let t = (u - self.lo) / self.step;
        let i = (t as usize).min(self.f.len() - 2);
        let w = t - i as f64;
        (self.f[i] * (1.0 - w) + self.f[i + 1] * w).exp()
    }
}

impl SaEstimator {
    /// Densities → scores (the post-KDE half of Algorithm 1). Exposed so
    /// Figure 2 can feed true densities.
    pub fn scores_from_density(
        &self,
        p_hat: &[f64],
        kernel: &Kernel,
        lambda: f64,
        d: usize,
    ) -> Vec<f64> {
        let sd = SpectralDensity::new(kernel, d);
        let n = p_hat.len();
        let gl = GaussLegendre::new(32);
        let stab = |p: f64| {
            let p = p.max(1e-300);
            if self.stabilize {
                stabilize_density(p, n, self.stab_coef)
            } else {
                p
            }
        };
        // The RQ spectral density has no closed form — under ClosedForm
        // it takes the pool-parallel quadrature route (same results as
        // SaIntegration::Quadrature, thread-count invariant).
        let integration = if matches!(sd.spec, KernelSpec::RationalQuadratic { .. }) {
            SaIntegration::Quadrature
        } else {
            self.integration
        };
        match integration {
            SaIntegration::ClosedForm => {
                // Gaussian fast path: one polylog table, O(1) per point.
                if let KernelSpec::Gaussian { sigma } = sd.spec {
                    if n > 64 {
                        let c = (2.0 * PI * sigma * sigma).powf(d as f64 / 2.0);
                        let ys: Vec<f64> =
                            p_hat.iter().map(|&p| stab(p) * c / lambda).collect();
                        let (y_min, y_max) = ys.iter().fold(
                            (f64::INFINITY, 0.0_f64),
                            |(lo, hi), &y| (lo.min(y), hi.max(y)),
                        );
                        let table = PolylogTable::new(d as f64 / 2.0, y_min, y_max);
                        // K̃ = −Li_{d/2}(−y)/(p·c) and p·c = y·λ
                        return ys.iter().map(|&y| table.neg_li(y) / (y * lambda)).collect();
                    }
                }
                p_hat.iter().map(|&p| sa_value_closed_form(stab(p), &sd, lambda)).collect()
            }
            SaIntegration::Quadrature => {
                // per-point quadrature on the shared pool (each point's
                // panels are evaluated independently → thread-count
                // invariant)
                let _span = trace::span("leverage.sa.quadrature");
                crate::util::pool::par_rows(n, |i| {
                    sa_value_quadrature(stab(p_hat[i]), &sd, lambda, &gl)
                })
            }
        }
    }
}

impl LeverageEstimator for SaEstimator {
    fn name(&self) -> &'static str {
        match self.integration {
            SaIntegration::ClosedForm => "sa",
            SaIntegration::Quadrature => "sa-quadrature",
        }
    }

    fn estimate(&self, ctx: &LeverageContext, rng: &mut Rng) -> Vec<f64> {
        let _span = trace::span("leverage.sa");
        let n = ctx.n();
        let p_hat: Vec<f64> = {
            let _kde = trace::span("leverage.sa.density");
            if self.use_true_density {
                ctx.p_true
                    .expect("use_true_density requires ctx.p_true")
                    .to_vec()
            } else {
                let h = self
                    .bandwidth
                    .unwrap_or_else(|| kde::bandwidth::scott(n, ctx.d()));
                let mut p = kde::density_at_points(ctx.x, h, self.kde, rng);
                if self.loo {
                    for pi in &mut p {
                        *pi = kde::loo_correct(*pi, n, ctx.d(), h);
                    }
                }
                p
            }
        };
        let _scores = trace::span("leverage.sa.scores");
        self.scores_from_density(&p_hat, ctx.kernel, ctx.lambda, ctx.d())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, KernelSpec};
    use crate::quadrature::integrate_semi_infinite;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-300)
    }

    #[test]
    fn spectral_density_integrates_to_one() {
        // ∫_{R^d} m(s) ds = K(0) = 1 for the true-constant Matérn density.
        for (nu, d) in [(0.5f64, 1usize), (1.5, 1), (1.5, 3), (2.5, 3), (0.5, 5)] {
            let a = (2.0 * nu).sqrt();
            let k = Kernel::new(KernelSpec::Matern { nu, a });
            let sd = SpectralDensity::new(&k, d);
            let omega = sphere_surface(d);
            let got = integrate_semi_infinite(
                |r| sd.eval(r) * omega * r.powi(d as i32 - 1),
                1e-12,
            );
            assert!(rel(got, 1.0) < 1e-5, "nu={nu} d={d}: ∫m = {got}");
        }
    }

    #[test]
    fn spectral_density_matches_kernel_by_inverse_transform_1d() {
        // 1-d check: K(u) = ∫ m(r) e^{2πiru} dr = 2∫₀^∞ m(r)cos(2πru) dr.
        let nu = 1.5f64;
        let a = (2.0 * nu).sqrt();
        let k = Kernel::new(KernelSpec::Matern { nu, a });
        let sd = SpectralDensity::new(&k, 1);
        for &u in &[0.1, 0.5, 1.0] {
            let got = integrate_semi_infinite(
                |r| 2.0 * sd.eval(r) * (2.0 * PI * r * u).cos(),
                1e-11,
            );
            let want = k.eval_sq(u * u);
            assert!(rel(got, want) < 1e-4, "u={u}: {got} vs {want}");
        }
    }

    #[test]
    fn closed_form_matches_quadrature_matern() {
        let gl = GaussLegendre::new(32);
        for (nu, d) in [(1.5f64, 1usize), (1.5, 3), (0.5, 3), (2.5, 2)] {
            let a = (2.0 * nu).sqrt();
            let k = Kernel::new(KernelSpec::Matern { nu, a });
            let sd = SpectralDensity::new(&k, d);
            let lambda = 1e-5; // closed form is exact as λ→0
            for &p in &[0.2, 1.0, 5.0] {
                let cf = sa_value_closed_form(p, &sd, lambda);
                let q = sa_value_quadrature(p, &sd, lambda, &gl);
                assert!(
                    rel(cf, q) < 0.05,
                    "nu={nu} d={d} p={p}: closed={cf} quad={q}"
                );
            }
        }
    }

    #[test]
    fn closed_form_matches_quadrature_gaussian() {
        let gl = GaussLegendre::new(48);
        for d in [1usize, 3] {
            let k = Kernel::new(KernelSpec::Gaussian { sigma: 0.4 });
            let sd = SpectralDensity::new(&k, d);
            for &(p, lambda) in &[(1.0, 1e-3), (0.3, 1e-5), (4.0, 1e-4)] {
                let cf = sa_value_closed_form(p, &sd, lambda);
                let q = sa_value_quadrature(p, &sd, lambda, &gl);
                assert!(rel(cf, q) < 0.02, "d={d} p={p} λ={lambda}: {cf} vs {q}");
            }
        }
    }

    #[test]
    fn laplacian_closed_form_matches_quadrature() {
        let gl = GaussLegendre::new(32);
        for d in [1usize, 2, 3] {
            let k = Kernel::new(KernelSpec::Laplacian { gamma: 1.0 });
            let sd = SpectralDensity::new(&k, d);
            let lambda = 1e-5;
            for &p in &[0.2, 1.0, 5.0] {
                let cf = sa_value_closed_form(p, &sd, lambda);
                let q = sa_value_quadrature(p, &sd, lambda, &gl);
                assert!(rel(cf, q) < 0.05, "d={d} p={p}: closed={cf} quad={q}");
            }
        }
    }

    #[test]
    fn rq_closed_form_entry_point_is_the_quadrature() {
        // sa_value_closed_form routes RQ through quadrature with the same
        // 32-node rule — the two entry points must agree exactly.
        let k = Kernel::new(KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.5 });
        let sd = SpectralDensity::new(&k, 2);
        let gl = GaussLegendre::new(32);
        for &(p, lambda) in &[(0.5, 1e-4), (2.0, 1e-3), (0.05, 1e-5)] {
            let cf = sa_value_closed_form(p, &sd, lambda);
            let q = sa_value_quadrature(p, &sd, lambda, &gl);
            assert!(cf.is_finite() && cf > 0.0, "p={p} λ={lambda}: {cf}");
            assert_eq!(cf.to_bits(), q.to_bits(), "p={p} λ={lambda}");
        }
    }

    #[test]
    fn rq_scores_positive_finite_and_decreasing_in_density() {
        // Batch entry point: RQ under ClosedForm silently takes the
        // quadrature route; scores must behave like every other kernel's.
        let k = Kernel::new(KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.4 });
        let est = SaEstimator { stabilize: false, ..Default::default() };
        let p_hat = [0.05, 0.2, 1.0, 5.0];
        let scores = est.scores_from_density(&p_hat, &k, 1e-4, 2);
        for (i, &s) in scores.iter().enumerate() {
            assert!(s.is_finite() && s > 0.0, "i={i}: {s}");
            if i > 0 {
                assert!(s < scores[i - 1], "not decreasing at i={i}");
            }
        }
        // and the batch path agrees with the per-point evaluator
        let sd = SpectralDensity::new(&k, 2);
        for (i, &p) in p_hat.iter().enumerate() {
            let direct = sa_value_closed_form(p, &sd, 1e-4);
            assert!(rel(scores[i], direct) < 1e-12, "i={i}");
        }
    }

    #[test]
    fn sa_decreasing_in_density() {
        // The paper's rule of thumb: leverage larger where density smaller.
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let sd = SpectralDensity::new(&k, 3);
        let lambda = 1e-4;
        let v_low = sa_value_closed_form(0.05, &sd, lambda);
        let v_hi = sa_value_closed_form(5.0, &sd, lambda);
        assert!(v_low > v_hi, "{v_low} vs {v_hi}");
        // exponent check: K̃ ∝ p^{d/2α−1} ⇒ ratio = (p1/p2)^{d/2α−1}
        let alpha = 1.5 + 1.5;
        let want = (0.05f64 / 5.0).powf(3.0 / (2.0 * alpha) - 1.0);
        assert!(rel(v_low / v_hi, want) < 1e-9);
    }

    #[test]
    fn stabilization_only_lifts_small_densities() {
        let n = 10_000;
        let h0 = 0.3 * (n as f64).powf(-0.8);
        assert_eq!(stabilize_density(1.0, n, 0.3), 1.0);
        let tiny = h0 / 10.0;
        let s = stabilize_density(tiny, n, 0.3);
        assert!(s > tiny && s < h0, "{tiny} → {s} (h0={h0})");
    }

    #[test]
    fn gaussian_table_fast_path_matches_direct() {
        // The polylog interpolation table must agree with per-point
        // closed-form evaluation to ≪ KDE error.
        let k = Kernel::new(KernelSpec::Gaussian { sigma: 0.9 });
        let d = 5;
        let sd = SpectralDensity::new(&k, d);
        let lambda = 3e-4;
        let est = SaEstimator { stabilize: false, ..Default::default() };
        let mut rng = Rng::seed_from_u64(3);
        let p_hat: Vec<f64> = (0..500).map(|_| 10f64.powf(rng.range(-6.0, 2.0))).collect();
        let fast = est.scores_from_density(&p_hat, &k, lambda, d);
        for (i, &p) in p_hat.iter().enumerate() {
            let direct = sa_value_closed_form(p, &sd, lambda);
            assert!(
                rel(fast[i], direct) < 1e-4,
                "i={i} p={p}: fast {} vs direct {direct}",
                fast[i]
            );
        }
    }

    #[test]
    fn sa_tracks_exact_leverage_1d_uniform() {
        // Mini Figure-2: SA with true density vs exact G on Unif[0,1].
        // Interior points (away from the boundary, where Assumption 4
        // holds comfortably) must agree within ~20% at n=1500.
        use crate::data::{dist1d, Dist1d};
        let mut rng = Rng::seed_from_u64(4);
        let n = 1500;
        let ds = dist1d(Dist1d::Uniform, n, &mut rng);
        let nu = 1.5f64;
        let k = Kernel::new(KernelSpec::Matern { nu, a: (2.0 * nu).sqrt() });
        let lam = crate::krr::lambda::fig2(n);
        let g = crate::leverage::exact::rescaled_leverage_exact(&ds.x, &k, lam);
        let est = SaEstimator { use_true_density: true, ..Default::default() };
        let ctx = crate::leverage::LeverageContext {
            x: &ds.x,
            kernel: &k,
            lambda: lam,
            p_true: ds.p_true.as_deref(),
            inner_m: 16,
            cache: None,
        };
        let sa = est.estimate(&ctx, &mut rng);
        let mut rels = Vec::new();
        for i in 0..n {
            let xi = ds.x[(i, 0)];
            if (0.15..=0.85).contains(&xi) {
                rels.push(rel(sa[i], g[i]));
            }
        }
        rels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = rels[rels.len() / 2];
        assert!(med < 0.2, "median interior relative error {med}");
    }
}
