//! Statistical leverage score estimation.
//!
//! The rescaled leverage score of design point x_i is
//! G_λ(x_i,x_i) = n·[K_n(K_n + nλI)^{−1}]_ii (paper §2.3); importance
//! sampling the Nyström landmarks proportionally to {G_λ(x_i,x_i)}
//! preserves the KRR risk up to a constant (Theorem 2).
//!
//! Estimators:
//! * [`sa::SaEstimator`] — **the paper's contribution**: Õ(n) analytic
//!   approximation via KDE + the spectral integral (Eqn 6).
//! * [`exact::ExactEstimator`] — O(n³) Cholesky ground truth.
//! * [`UniformEstimator`] — the "Vanilla" baseline (all-equal scores).
//! * [`rls::RecursiveRls`] — Musco & Musco (2017), Õ(n·m²).
//! * [`bless::Bless`] — Rudi et al. (2018) bottom-up path following.
//!
//! All estimators return *unnormalized* scores proportional to
//! G_λ(x_i,x_i) (exact scale for `exact` and `sa`, so Figure 2 can
//! overlay them); normalize with [`normalize`] to get sampling
//! probabilities.

pub mod bless;
pub mod exact;
pub mod rls;
pub mod sa;

use crate::kernels::Kernel;
use crate::linalg::{GramCache, Mat};
use crate::util::rng::Rng;
use std::cell::RefCell;

/// Everything an estimator may need.
pub struct LeverageContext<'a> {
    pub x: &'a Mat,
    pub kernel: &'a Kernel,
    pub lambda: f64,
    /// True input density at the design points, when the generator knows
    /// it (synthetic designs) — used by SA's oracle mode in tests.
    pub p_true: Option<&'a [f64]>,
    /// Internal subsample / dictionary size for the iterative baselines
    /// (the paper's `s = 1·n^{1/3}`-style setting).
    pub inner_m: usize,
    /// Shared landmark Gram workspace ([`crate::linalg::gramcache`]).
    /// The landmark-block estimators (Recursive-RLS, BLESS) extend it
    /// level by level instead of reassembling K_·J, and the pipeline can
    /// hand the same workspace to the Nyström stage afterwards so
    /// already-evaluated landmark columns are never paid twice. `None` →
    /// estimators that need one build a private caching workspace
    /// (bit-identical results either way).
    pub cache: Option<&'a RefCell<GramCache<'a>>>,
}

impl<'a> LeverageContext<'a> {
    pub fn new(x: &'a Mat, kernel: &'a Kernel, lambda: f64) -> Self {
        let n = x.rows;
        LeverageContext {
            x,
            kernel,
            lambda,
            p_true: None,
            inner_m: ((n as f64).powf(1.0 / 3.0).round() as usize).max(8),
            cache: None,
        }
    }

    /// Attach a shared landmark Gram workspace (must be keyed to the
    /// same point set as `self.x`; the estimators assert this).
    pub fn with_cache(mut self, cache: &'a RefCell<GramCache<'a>>) -> Self {
        self.cache = Some(cache);
        self
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }
}

/// A leverage score estimator.
pub trait LeverageEstimator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Unnormalized scores ∝ G_λ(x_i, x_i), length n, all ≥ 0 and finite.
    fn estimate(&self, ctx: &LeverageContext, rng: &mut Rng) -> Vec<f64>;
}

/// Normalize scores into a sampling distribution q (Σq = 1).
pub fn normalize(scores: &[f64]) -> Vec<f64> {
    let total: f64 = scores.iter().sum();
    assert!(
        total > 0.0 && total.is_finite(),
        "scores must have positive finite total, got {total}"
    );
    scores.iter().map(|s| s / total).collect()
}

/// The "Vanilla" baseline: uniform sampling probabilities.
pub struct UniformEstimator;

impl LeverageEstimator for UniformEstimator {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn estimate(&self, ctx: &LeverageContext, _rng: &mut Rng) -> Vec<f64> {
        vec![1.0; ctx.n()]
    }
}

/// CLI-facing method selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeverageMethod {
    Exact,
    Sa,
    /// SA forced through the numerical-quadrature path (validation mode).
    SaQuadrature,
    Uniform,
    RecursiveRls,
    Bless,
}

impl LeverageMethod {
    pub fn parse(s: &str) -> Result<LeverageMethod, String> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Ok(LeverageMethod::Exact),
            "sa" => Ok(LeverageMethod::Sa),
            "sa-quadrature" | "sa-int" => Ok(LeverageMethod::SaQuadrature),
            "uniform" | "vanilla" => Ok(LeverageMethod::Uniform),
            "rc" | "recursive-rls" | "rls" => Ok(LeverageMethod::RecursiveRls),
            "bless" => Ok(LeverageMethod::Bless),
            _ => Err(format!(
                "unknown method '{s}' (exact|sa|sa-quadrature|uniform|rc|bless)"
            )),
        }
    }

    pub fn build(self) -> Box<dyn LeverageEstimator> {
        match self {
            LeverageMethod::Exact => Box::new(exact::ExactEstimator),
            LeverageMethod::Sa => Box::new(sa::SaEstimator::default()),
            LeverageMethod::SaQuadrature => Box::new(sa::SaEstimator {
                integration: sa::SaIntegration::Quadrature,
                ..Default::default()
            }),
            LeverageMethod::Uniform => Box::new(UniformEstimator),
            LeverageMethod::RecursiveRls => Box::new(rls::RecursiveRls::default()),
            LeverageMethod::Bless => Box::new(bless::Bless::default()),
        }
    }

    pub fn all_comparison() -> [LeverageMethod; 4] {
        [
            LeverageMethod::Sa,
            LeverageMethod::Uniform,
            LeverageMethod::RecursiveRls,
            LeverageMethod::Bless,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelSpec;

    #[test]
    fn normalize_sums_to_one() {
        crate::util::prop::check_vec_f64(
            11,
            100,
            |rng| crate::util::prop::gen::weights(rng, 50),
            |w| {
                let q = normalize(w);
                (q.iter().sum::<f64>() - 1.0).abs() < 1e-12 && q.iter().all(|&v| v >= 0.0)
            },
        );
    }

    #[test]
    fn uniform_is_flat() {
        let mut rng = Rng::seed_from_u64(1);
        let x = Mat::zeros(10, 2);
        let k = Kernel::new(KernelSpec::Matern { nu: 0.5, a: 1.0 });
        let ctx = LeverageContext::new(&x, &k, 0.1);
        let s = UniformEstimator.estimate(&ctx, &mut rng);
        assert_eq!(s, vec![1.0; 10]);
    }

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("exact", LeverageMethod::Exact),
            ("sa", LeverageMethod::Sa),
            ("sa-quadrature", LeverageMethod::SaQuadrature),
            ("vanilla", LeverageMethod::Uniform),
            ("rc", LeverageMethod::RecursiveRls),
            ("bless", LeverageMethod::Bless),
        ] {
            assert_eq!(LeverageMethod::parse(s).unwrap(), m);
        }
        assert!(LeverageMethod::parse("nope").is_err());
    }
}
