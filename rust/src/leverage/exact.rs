//! Exact rescaled leverage scores via Cholesky — O(n³) ground truth.

use super::{LeverageContext, LeverageEstimator};
use crate::linalg::Cholesky;
use crate::trace;
use crate::util::rng::Rng;

/// diag(K(K+nλI)^{−1}) computed exactly. Used as the reference in Table 1
/// and Figure 2; also the only estimator with no randomness.
pub struct ExactEstimator;

/// Exact rescaled leverage scores G_λ(x_i,x_i) without needing responses.
/// K_n is assembled through the blocked distance/Gram engine
/// (`linalg::blocked` via [`crate::kernels::Kernel::matrix_sym`]); the
/// n-RHS identity solve goes through the blocked multi-RHS engine
/// ([`Cholesky::inv_quad_diag`]) instead of n independent scalar e_i
/// solves, and stays bit-identical for any thread count.
pub fn rescaled_leverage_exact(
    x: &crate::linalg::Mat,
    kernel: &crate::kernels::Kernel,
    lambda: f64,
) -> Vec<f64> {
    let n = x.rows;
    let mut a = kernel.matrix_sym(x);
    a.add_diag(n as f64 * lambda);
    let chol = Cholesky::factor_jittered(&a).expect("K + nλI must be PD");
    let nlam = n as f64 * lambda;
    let q = chol.inv_quad_diag();
    // G_i = n(1 − nλ·eᵢᵀ(K+nλI)^{−1}eᵢ)
    q.into_iter().map(|qi| n as f64 * (1.0 - nlam * qi)).collect()
}

impl LeverageEstimator for ExactEstimator {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn estimate(&self, ctx: &LeverageContext, _rng: &mut Rng) -> Vec<f64> {
        let _span = trace::span("leverage.exact");
        rescaled_leverage_exact(ctx.x, ctx.kernel, ctx.lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::{Kernel, KernelSpec};
    use crate::leverage::LeverageContext;

    #[test]
    fn exact_scores_positive_and_bounded() {
        let mut rng = Rng::seed_from_u64(1);
        let ds = data::dist1d(data::Dist1d::Uniform, 120, &mut rng);
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let lam = crate::krr::lambda::fig2(ds.n());
        let ctx = LeverageContext::new(&ds.x, &k, lam);
        let g = ExactEstimator.estimate(&ctx, &mut rng);
        for (i, &gi) in g.iter().enumerate() {
            // ℓ_i = G_i/n ∈ (0,1)
            assert!(gi > 0.0 && gi < ds.n() as f64, "i={i} G={gi}");
        }
        // statistical dimension consistency: Σℓ = d_stat ∈ (0, n)
        let dstat: f64 = g.iter().sum::<f64>() / ds.n() as f64;
        assert!(dstat > 1.0 && dstat < ds.n() as f64, "dstat={dstat}");
    }

    #[test]
    fn boundary_points_have_higher_leverage_uniform_design() {
        // For Unif[0,1], exact rescaled leverage is larger near 0/1
        // (fewer neighbors share the load) — a qualitative invariant the
        // paper's Figure 2 displays.
        let mut rng = Rng::seed_from_u64(2);
        let ds = data::dist1d(data::Dist1d::Uniform, 300, &mut rng);
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let lam = crate::krr::lambda::fig2(ds.n());
        let g = rescaled_leverage_exact(&ds.x, &k, lam);
        let (mut edge, mut ne, mut mid, mut nm) = (0.0, 0, 0.0, 0);
        for i in 0..ds.n() {
            let xi = ds.x[(i, 0)];
            if xi < 0.02 || xi > 0.98 {
                edge += g[i];
                ne += 1;
            } else if (0.4..0.6).contains(&xi) {
                mid += g[i];
                nm += 1;
            }
        }
        if ne > 0 && nm > 0 {
            assert!(
                edge / ne as f64 > mid / nm as f64,
                "edge {} vs mid {}",
                edge / ne as f64,
                mid / nm as f64
            );
        }
    }

    #[test]
    fn matches_krr_leverage_path() {
        let mut rng = Rng::seed_from_u64(3);
        let ds = data::dist1d(data::Dist1d::Bimodal, 80, &mut rng);
        let k = Kernel::new(KernelSpec::Matern { nu: 0.5, a: 1.0 });
        let lam = 1e-2;
        let via_krr = crate::krr::ExactKrr::fit(k.clone(), &ds.x, &ds.y, lam)
            .unwrap()
            .rescaled_leverage();
        let direct = rescaled_leverage_exact(&ds.x, &k, lam);
        for i in 0..ds.n() {
            assert!((via_krr[i] - direct[i]).abs() < 1e-7);
        }
    }
}
