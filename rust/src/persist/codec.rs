//! Dependency-free binary codec for model artifacts.
//!
//! ## File layout
//!
//! ```text
//!   offset  size  field
//!   0       4     magic "LKRR"
//!   4       2     format version (u16 LE, currently 1)
//!   6       2     artifact kind  (u16 LE: 1 = model, 2 = stream checkpoint)
//!   8       …     sections, back to back:
//!             4     section tag (ASCII, e.g. "MODL")
//!             8     payload length (u64 LE)
//!             len   payload
//!             4     CRC32 (IEEE) of the payload (u32 LE)
//! ```
//!
//! Every `f64` is stored as its IEEE-754 **bit pattern** (`to_bits`, LE) —
//! no text formatting anywhere — so `decode(encode(x))` reproduces every
//! float bit for bit, which is what lets a loaded model predict
//! bit-identically to the fitted one and a restored stream checkpoint
//! replay bit-identically to an uninterrupted run.
//!
//! ## Compatibility rules
//!
//! * The magic never changes; a file without it is rejected as
//!   [`PersistError::BadMagic`].
//! * `FORMAT_VERSION` bumps on any layout change; readers reject files
//!   from a *newer* writer ([`PersistError::UnsupportedVersion`]) and are
//!   expected to keep decoding every older version they ever shipped.
//! * Unknown section tags are ignored on read (forward-compatible
//!   additions); a missing required section is
//!   [`PersistError::Malformed`].
//! * Corruption anywhere in a payload is caught by the per-section CRC
//!   ([`PersistError::ChecksumMismatch`]); a short file is
//!   [`PersistError::Truncated`]. A decoder never panics on bad input and
//!   never returns a half-decoded value.

use super::PersistError;
use crate::coordinator::{FitReport, FittedModel};
use crate::kernels::{Kernel, KernelSpec};
use crate::linalg::{Cholesky, Mat};
use crate::nystrom::NystromKrr;
use crate::runtime::Backend;
use crate::stream::{
    CheckpointPolicy, IncrementalModel, OnlineDictionary, RefreshPolicy, StreamCheckpoint,
    StreamConfig,
};

/// File magic: first four bytes of every artifact.
pub const MAGIC: [u8; 4] = *b"LKRR";

/// Current writer format version (see module docs for the rules).
pub const FORMAT_VERSION: u16 = 1;

/// What an artifact file contains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum ArtifactKind {
    /// A servable [`FittedModel`].
    Model = 1,
    /// A full [`StreamCheckpoint`] (config + model + replay progress).
    Checkpoint = 2,
}

impl ArtifactKind {
    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Model => "model",
            ArtifactKind::Checkpoint => "checkpoint",
        }
    }

    fn from_u16(v: u16) -> Option<ArtifactKind> {
        match v {
            1 => Some(ArtifactKind::Model),
            2 => Some(ArtifactKind::Checkpoint),
            _ => None,
        }
    }
}

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — the standard zip/png
/// checksum, table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

// ---------------------------------------------------------------------------
// byte-level writer / reader
// ---------------------------------------------------------------------------

/// Append-only payload builder.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Exact bit pattern — the codec's float representation everywhere.
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked payload reader. Every accessor returns
/// [`PersistError::Truncated`] instead of panicking when the payload is
/// short.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Guard an upcoming allocation: `n` bytes must still be present.
    fn ensure(&self, n: usize) -> Result<(), PersistError> {
        if self.remaining() < n {
            Err(PersistError::Truncated)
        } else {
            Ok(())
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        self.ensure(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, PersistError> {
        Ok(self.take(1)?[0])
    }

    pub fn u64(&mut self) -> Result<u64, PersistError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, PersistError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str_owned(&mut self) -> Result<String, PersistError> {
        let n = self.u64()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| PersistError::Malformed("invalid utf-8 in string".into()))
    }

    /// A `u64` that must fit a `usize` count of `elem_bytes`-sized items
    /// still present in the payload — rejects corrupt giant lengths
    /// before any allocation.
    pub fn len_of(&mut self, elem_bytes: usize) -> Result<usize, PersistError> {
        let n = self.u64()?;
        let n: usize =
            n.try_into().map_err(|_| PersistError::Malformed("length overflow".into()))?;
        let total = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| PersistError::Malformed("length overflow".into()))?;
        self.ensure(total)?;
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Encode / Decode
// ---------------------------------------------------------------------------

/// Serialize into a [`Writer`] payload.
pub trait Encode {
    fn encode(&self, w: &mut Writer);
}

/// Deserialize from a [`Reader`]; must consume exactly what `encode`
/// wrote and never panic on malformed input.
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError>;
}

impl Encode for u64 {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(*self);
    }
}

impl Decode for u64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        r.u64()
    }
}

impl Encode for usize {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(*self as u64);
    }
}

impl Decode for usize {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        r.u64()?
            .try_into()
            .map_err(|_| PersistError::Malformed("usize overflow".into()))
    }
}

impl Encode for f64 {
    fn encode(&self, w: &mut Writer) {
        w.put_f64(*self);
    }
}

impl Decode for f64 {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        r.f64()
    }
}

impl Encode for bool {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(*self as u8);
    }
}

impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        match r.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(PersistError::Malformed("invalid bool".into())),
        }
    }
}

impl Encode for String {
    fn encode(&self, w: &mut Writer) {
        w.put_str(self);
    }
}

impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        r.str_owned()
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, w: &mut Writer) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.encode(w);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            _ => Err(PersistError::Malformed("invalid option tag".into())),
        }
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.len() as u64);
        for v in self {
            v.encode(w);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        // minimum 1 byte per element bounds the claimed length by the
        // payload that is actually present (no allocation bombs)
        let n = r.len_of(1)?;
        let mut out = Vec::new();
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Ok(out)
    }
}

impl Encode for Mat {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.rows as u64);
        w.put_u64(self.cols as u64);
        for &x in &self.data {
            w.put_f64(x);
        }
    }
}

impl Decode for Mat {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let rows: usize = Decode::decode(r)?;
        let cols: usize = Decode::decode(r)?;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| PersistError::Malformed("matrix shape overflow".into()))?;
        let total = n
            .checked_mul(8)
            .ok_or_else(|| PersistError::Malformed("matrix shape overflow".into()))?;
        r.ensure(total)?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(r.f64()?);
        }
        Ok(Mat { rows, cols, data })
    }
}

impl Encode for Cholesky {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.n() as u64);
        w.put_f64(self.jitter);
        for &x in &self.l {
            w.put_f64(x);
        }
    }
}

impl Decode for Cholesky {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let n: usize = Decode::decode(r)?;
        let jitter = r.f64()?;
        let total = n
            .checked_mul(n)
            .and_then(|s| s.checked_mul(8))
            .ok_or_else(|| PersistError::Malformed("factor shape overflow".into()))?;
        r.ensure(total)?;
        let mut l = Vec::with_capacity(n * n);
        for _ in 0..n * n {
            l.push(r.f64()?);
        }
        // The transposed-factor cache is never serialized: it is a pure
        // derived view, rebuilt lazily on the first backward solve.
        Ok(Cholesky { l, n, jitter, ut: std::sync::OnceLock::new() })
    }
}

impl Encode for KernelSpec {
    fn encode(&self, w: &mut Writer) {
        match self {
            KernelSpec::Matern { nu, a } => {
                w.put_u8(0);
                w.put_f64(*nu);
                w.put_f64(*a);
            }
            KernelSpec::Gaussian { sigma } => {
                w.put_u8(1);
                w.put_f64(*sigma);
            }
            KernelSpec::Laplacian { gamma } => {
                w.put_u8(2);
                w.put_f64(*gamma);
            }
            KernelSpec::RationalQuadratic { alpha, ell } => {
                w.put_u8(3);
                w.put_f64(*alpha);
                w.put_f64(*ell);
            }
        }
    }
}

impl Decode for KernelSpec {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        match r.u8()? {
            0 => Ok(KernelSpec::Matern { nu: r.f64()?, a: r.f64()? }),
            1 => Ok(KernelSpec::Gaussian { sigma: r.f64()? }),
            2 => Ok(KernelSpec::Laplacian { gamma: r.f64()? }),
            3 => Ok(KernelSpec::RationalQuadratic { alpha: r.f64()?, ell: r.f64()? }),
            _ => Err(PersistError::Malformed("unknown kernel tag".into())),
        }
    }
}

impl Encode for Kernel {
    fn encode(&self, w: &mut Writer) {
        self.spec.encode(w);
    }
}

impl Decode for Kernel {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        // the Matérn normalization constant is a pure function of ν, so
        // `Kernel::new` rebuilds it bit-identically from the spec
        Ok(Kernel::new(KernelSpec::decode(r)?))
    }
}

impl Encode for RefreshPolicy {
    fn encode(&self, w: &mut Writer) {
        self.every.encode(w);
        w.put_f64(self.drift);
    }
}

impl Decode for RefreshPolicy {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        Ok(RefreshPolicy { every: Decode::decode(r)?, drift: r.f64()? })
    }
}

impl Encode for CheckpointPolicy {
    fn encode(&self, w: &mut Writer) {
        self.every.encode(w);
        self.dir.encode(w);
        self.name.encode(w);
        self.keep_last.encode(w);
    }
}

impl Decode for CheckpointPolicy {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        Ok(CheckpointPolicy {
            every: Decode::decode(r)?,
            dir: Decode::decode(r)?,
            name: Decode::decode(r)?,
            keep_last: Decode::decode(r)?,
        })
    }
}

impl Encode for StreamConfig {
    fn encode(&self, w: &mut Writer) {
        self.kernel.encode(w);
        w.put_f64(self.mu);
        self.budget.encode(w);
        w.put_f64(self.accept_threshold);
        self.refresh.encode(w);
        self.threads.encode(w);
        self.checkpoint.encode(w);
    }
}

impl Decode for StreamConfig {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let cfg = StreamConfig {
            kernel: Decode::decode(r)?,
            mu: r.f64()?,
            budget: Decode::decode(r)?,
            accept_threshold: r.f64()?,
            refresh: Decode::decode(r)?,
            threads: Decode::decode(r)?,
            checkpoint: Decode::decode(r)?,
        };
        if !(cfg.mu > 0.0 && cfg.mu.is_finite()) {
            return Err(PersistError::Malformed("stream config: μ must be positive".into()));
        }
        if cfg.budget == 0 {
            return Err(PersistError::Malformed("stream config: zero budget".into()));
        }
        if !(0.0..1.0).contains(&cfg.accept_threshold) {
            return Err(PersistError::Malformed(
                "stream config: accept threshold outside [0, 1)".into(),
            ));
        }
        Ok(cfg)
    }
}

impl Encode for NystromKrr {
    fn encode(&self, w: &mut Writer) {
        self.kernel.encode(w);
        self.landmarks.encode(w);
        self.idx.encode(w);
        self.beta.encode(w);
        w.put_f64(self.lambda);
    }
}

impl Decode for NystromKrr {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let kernel = Kernel::decode(r)?;
        let landmarks = Mat::decode(r)?;
        let idx: Vec<usize> = Decode::decode(r)?;
        let beta: Vec<f64> = Decode::decode(r)?;
        let lambda = r.f64()?;
        let m = landmarks.rows;
        if beta.len() != m || idx.len() != m {
            return Err(PersistError::Malformed(format!(
                "landmark/β/idx arity mismatch: m={m}, β={}, idx={}",
                beta.len(),
                idx.len()
            )));
        }
        Ok(NystromKrr { kernel, landmarks, idx, beta, lambda })
    }
}

impl Encode for FittedModel {
    fn encode(&self, w: &mut Writer) {
        // backend/report timings are deliberately not persisted: the
        // artifact is the servable math — kernel, landmarks, β, λ, q —
        // plus the n_train provenance, nothing environment-specific
        self.nystrom.encode(w);
        self.q.encode(w);
        self.n_train.encode(w);
    }
}

impl Decode for FittedModel {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let nystrom = NystromKrr::decode(r)?;
        let q: Vec<f64> = Decode::decode(r)?;
        let n_train: u64 = Decode::decode(r)?;
        let report = FitReport {
            m_sub: nystrom.m(),
            backend: "native",
            method: "artifact",
            ..Default::default()
        };
        Ok(FittedModel { nystrom, report, backend: Backend::Native, q, n_train })
    }
}

impl Encode for OnlineDictionary {
    fn encode(&self, w: &mut Writer) {
        self.kernel.encode(w);
        self.budget.encode(w);
        w.put_f64(self.accept_threshold);
        w.put_f64(self.evict_margin);
        w.put_f64(self.eps);
        self.atoms.encode(w);
        self.arrival.encode(w);
        self.chol.encode(w);
        self.cached_scores.encode(w);
    }
}

impl Decode for OnlineDictionary {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let dict = OnlineDictionary {
            kernel: Kernel::decode(r)?,
            budget: Decode::decode(r)?,
            accept_threshold: r.f64()?,
            evict_margin: r.f64()?,
            eps: r.f64()?,
            atoms: Mat::decode(r)?,
            arrival: Decode::decode(r)?,
            chol: Decode::decode(r)?,
            cached_scores: Decode::decode(r)?,
        };
        let m = dict.atoms.rows;
        if dict.arrival.len() != m {
            return Err(PersistError::Malformed("dictionary arrival arity mismatch".into()));
        }
        if dict.budget == 0 || m > dict.budget {
            return Err(PersistError::Malformed("dictionary over budget".into()));
        }
        if let Some(ch) = &dict.chol {
            if ch.n() != m {
                return Err(PersistError::Malformed("dictionary factor arity mismatch".into()));
            }
        } else if m > 0 {
            return Err(PersistError::Malformed("non-empty dictionary without factor".into()));
        }
        if let Some(s) = &dict.cached_scores {
            if s.len() != m {
                return Err(PersistError::Malformed("cached score arity mismatch".into()));
            }
        }
        Ok(dict)
    }
}

impl Encode for IncrementalModel {
    fn encode(&self, w: &mut Writer) {
        self.kernel.encode(w);
        w.put_f64(self.mu);
        self.dict.encode(w);
        self.s.encode(w);
        self.rhs.encode(w);
        self.chol_a.encode(w);
        self.beta.encode(w);
        self.n_seen.encode(w);
    }
}

impl Decode for IncrementalModel {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let model = IncrementalModel {
            kernel: Kernel::decode(r)?,
            mu: r.f64()?,
            dict: OnlineDictionary::decode(r)?,
            s: Mat::decode(r)?,
            rhs: Decode::decode(r)?,
            chol_a: Decode::decode(r)?,
            beta: Decode::decode(r)?,
            n_seen: Decode::decode(r)?,
        };
        if !(model.mu > 0.0 && model.mu.is_finite()) {
            return Err(PersistError::Malformed("model ridge μ must be positive".into()));
        }
        let m = model.dict.len();
        if model.s.rows != m || model.s.cols != m || model.rhs.len() != m {
            return Err(PersistError::Malformed("streaming sums arity mismatch".into()));
        }
        if !(model.beta.len() == m || model.beta.is_empty()) {
            return Err(PersistError::Malformed("β arity mismatch".into()));
        }
        if let Some(ch) = &model.chol_a {
            if ch.n() != m {
                return Err(PersistError::Malformed("normal-equations factor arity mismatch".into()));
            }
        }
        Ok(model)
    }
}

// ---------------------------------------------------------------------------
// artifact files (header + CRC'd sections)
// ---------------------------------------------------------------------------

/// One decoded section: 4-byte ASCII tag + checksum-verified payload.
pub struct RawSection<'a> {
    pub tag: [u8; 4],
    pub payload: &'a [u8],
}

/// Assemble a complete artifact file from payload sections.
pub fn build_artifact(kind: ArtifactKind, sections: &[([u8; 4], &[u8])]) -> Vec<u8> {
    let total: usize = 8 + sections.iter().map(|(_, p)| 16 + p.len()).sum::<usize>();
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(kind as u16).to_le_bytes());
    for (tag, payload) in sections {
        out.extend_from_slice(tag);
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(payload);
        out.extend_from_slice(&crc32(payload).to_le_bytes());
    }
    out
}

/// Validate the header and split into checksum-verified sections.
pub fn parse_artifact(bytes: &[u8]) -> Result<(ArtifactKind, Vec<RawSection<'_>>), PersistError> {
    if bytes.len() < 8 {
        return Err(PersistError::Truncated);
    }
    if bytes[0..4] != MAGIC {
        return Err(PersistError::BadMagic);
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version == 0 || version > FORMAT_VERSION {
        return Err(PersistError::UnsupportedVersion { found: version });
    }
    let kind = ArtifactKind::from_u16(u16::from_le_bytes(bytes[6..8].try_into().unwrap()))
        .ok_or_else(|| PersistError::Malformed("unknown artifact kind".into()))?;
    let mut sections = Vec::new();
    let mut pos = 8usize;
    while pos < bytes.len() {
        if bytes.len() - pos < 12 {
            return Err(PersistError::Truncated);
        }
        let tag: [u8; 4] = bytes[pos..pos + 4].try_into().unwrap();
        let len = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        let len: usize =
            len.try_into().map_err(|_| PersistError::Malformed("section length overflow".into()))?;
        pos += 12;
        // checked arithmetic: a corrupt length near usize::MAX must be a
        // typed error, not an overflow panic (debug) or wrapped-guard
        // slice panic (release)
        let end = match len.checked_add(4).and_then(|l| pos.checked_add(l)) {
            Some(end) if end <= bytes.len() => end,
            _ => return Err(PersistError::Truncated),
        };
        let payload = &bytes[pos..pos + len];
        let stored = u32::from_le_bytes(bytes[pos + len..end].try_into().unwrap());
        if crc32(payload) != stored {
            return Err(PersistError::ChecksumMismatch {
                section: String::from_utf8_lossy(&tag).into_owned(),
            });
        }
        sections.push(RawSection { tag, payload });
        pos += len + 4;
    }
    Ok((kind, sections))
}

fn find_section<'a>(
    sections: &'a [RawSection<'a>],
    tag: &[u8; 4],
) -> Result<&'a RawSection<'a>, PersistError> {
    sections.iter().find(|s| &s.tag == tag).ok_or_else(|| {
        PersistError::Malformed(format!(
            "missing required section '{}'",
            String::from_utf8_lossy(tag)
        ))
    })
}

/// Decode one value from a section payload, requiring full consumption.
fn decode_section<T: Decode>(section: &RawSection<'_>) -> Result<T, PersistError> {
    let mut r = Reader::new(section.payload);
    let v = T::decode(&mut r)?;
    if r.remaining() != 0 {
        return Err(PersistError::Malformed(format!(
            "{} trailing bytes in section '{}'",
            r.remaining(),
            String::from_utf8_lossy(&section.tag)
        )));
    }
    Ok(v)
}

fn payload_of<T: Encode>(v: &T) -> Vec<u8> {
    let mut w = Writer::new();
    v.encode(&mut w);
    w.buf
}

/// Serialize a fitted model to a complete artifact file.
pub fn encode_model(model: &FittedModel) -> Vec<u8> {
    // META: human-debuggable provenance (n, m, d, kernel); the decoder
    // does not require it — the manifest is built from it at save time
    let mut meta = Writer::new();
    meta.put_u64(model.n_train);
    meta.put_u64(model.nystrom.m() as u64);
    meta.put_u64(model.nystrom.landmarks.cols as u64);
    meta.put_str(&model.nystrom.kernel.spec.name());
    let body = payload_of(model);
    build_artifact(
        ArtifactKind::Model,
        &[(*b"META", meta.buf.as_slice()), (*b"MODL", body.as_slice())],
    )
}

/// Decode a fitted model from artifact bytes.
pub fn decode_model(bytes: &[u8]) -> Result<FittedModel, PersistError> {
    let (kind, sections) = parse_artifact(bytes)?;
    if kind != ArtifactKind::Model {
        return Err(PersistError::WrongKind { expected: ArtifactKind::Model, found: kind });
    }
    decode_section(find_section(&sections, b"MODL")?)
}

/// The PRGS section: replay progress (everything in a
/// [`StreamCheckpoint`] besides the config and the model). One struct so
/// the encoder, decoder, and validation stay in one place.
struct Progress {
    window: Vec<f64>,
    window_cap: usize,
    err_at_publish: f64,
    since_publish: usize,
    origin: Option<String>,
}

impl Encode for Progress {
    fn encode(&self, w: &mut Writer) {
        self.window.encode(w);
        self.window_cap.encode(w);
        w.put_f64(self.err_at_publish);
        self.since_publish.encode(w);
        self.origin.encode(w);
    }
}

impl Decode for Progress {
    fn decode(r: &mut Reader<'_>) -> Result<Self, PersistError> {
        let p = Progress {
            window: Decode::decode(r)?,
            window_cap: Decode::decode(r)?,
            err_at_publish: r.f64()?,
            since_publish: Decode::decode(r)?,
            origin: Decode::decode(r)?,
        };
        // cap 0 would disable the window's eviction condition after
        // restore (the VecDeque would grow one f64 per arrival forever),
        // so it is as malformed as an over-full window
        if p.window_cap == 0 || p.window.len() > p.window_cap {
            return Err(PersistError::Malformed("invalid prequential window capacity".into()));
        }
        Ok(p)
    }
}

/// Serialize a stream checkpoint to a complete artifact file.
pub fn encode_checkpoint(chk: &StreamCheckpoint) -> Vec<u8> {
    let cfg = payload_of(&chk.cfg);
    let model = payload_of(&chk.model);
    let prgs = payload_of(&Progress {
        window: chk.window.clone(),
        window_cap: chk.window_cap,
        err_at_publish: chk.err_at_publish,
        since_publish: chk.since_publish,
        origin: chk.origin.clone(),
    });
    build_artifact(
        ArtifactKind::Checkpoint,
        &[
            (*b"CFG ", cfg.as_slice()),
            (*b"MODL", model.as_slice()),
            (*b"PRGS", prgs.as_slice()),
        ],
    )
}

/// Decode a stream checkpoint from artifact bytes.
pub fn decode_checkpoint(bytes: &[u8]) -> Result<StreamCheckpoint, PersistError> {
    let (kind, sections) = parse_artifact(bytes)?;
    if kind != ArtifactKind::Checkpoint {
        return Err(PersistError::WrongKind { expected: ArtifactKind::Checkpoint, found: kind });
    }
    let cfg: StreamConfig = decode_section(find_section(&sections, b"CFG ")?)?;
    let model: IncrementalModel = decode_section(find_section(&sections, b"MODL")?)?;
    let p: Progress = decode_section(find_section(&sections, b"PRGS")?)?;
    Ok(StreamCheckpoint {
        cfg,
        model,
        window: p.window,
        window_cap: p.window_cap,
        err_at_publish: p.err_at_publish,
        since_publish: p.since_publish,
        origin: p.origin,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_with_backend, FitConfig};
    use crate::data::{dist1d, Dist1d};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn roundtrip<T: Encode + Decode>(v: &T) -> T {
        let bytes = payload_of(v);
        let mut r = Reader::new(&bytes);
        let back = T::decode(&mut r).expect("decode");
        assert_eq!(r.remaining(), 0, "payload not fully consumed");
        back
    }

    fn bits(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn prop_vec_f64_roundtrip_bitwise() {
        // includes negative zero, subnormals, infinities and NaN payloads:
        // the codec must preserve the exact bit pattern of every f64
        prop::check(
            101,
            80,
            |rng| {
                let n = rng.usize(40);
                (0..n)
                    .map(|i| match i % 6 {
                        0 => rng.normal() * 10f64.powi(rng.usize(40) as i32 - 20),
                        1 => -0.0,
                        2 => f64::INFINITY,
                        3 => f64::from_bits(0x7FF8_0000_0000_1234), // NaN w/ payload
                        4 => f64::MIN_POSITIVE / 8.0,               // subnormal
                        _ => rng.normal(),
                    })
                    .collect::<Vec<f64>>()
            },
            |v| bits(&roundtrip(v)) == bits(v),
        );
    }

    #[test]
    fn prop_mat_roundtrip_bitwise_random_shapes() {
        prop::check(
            102,
            60,
            |rng| {
                let r = rng.usize(12);
                let c = if r == 0 { 0 } else { 1 + rng.usize(12) };
                Mat::from_fn(r, c, |_, _| rng.normal() * 1e3)
            },
            |m| {
                let back = roundtrip(m);
                back.rows == m.rows && back.cols == m.cols && bits(&back.data) == bits(&m.data)
            },
        );
    }

    #[test]
    fn prop_cholesky_roundtrip_bitwise() {
        prop::check(
            103,
            40,
            |rng| {
                let n = 1 + rng.usize(10);
                let a = Mat { rows: n, cols: n, data: prop::gen::spd(rng, n, 0.5) };
                Cholesky::factor_jittered(&a).unwrap()
            },
            |ch| {
                let back = roundtrip(ch);
                back.n() == ch.n()
                    && back.jitter.to_bits() == ch.jitter.to_bits()
                    && bits(&back.l) == bits(&ch.l)
            },
        );
    }

    #[test]
    fn scalar_and_container_roundtrips() {
        for spec in [
            KernelSpec::Matern { nu: 1.5, a: 1.732 },
            KernelSpec::Gaussian { sigma: 0.4 },
            KernelSpec::Laplacian { gamma: 2.25 },
            KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.375 },
        ] {
            assert_eq!(roundtrip(&spec), spec);
        }
        assert_eq!(roundtrip(&Some(7u64)), Some(7));
        assert_eq!(roundtrip(&None::<u64>), None);
        assert_eq!(roundtrip(&"héllo\nworld".to_string()), "héllo\nworld");
        assert_eq!(roundtrip(&true), true);
        assert_eq!(
            roundtrip(&RefreshPolicy { every: 17, drift: 0.25 }),
            RefreshPolicy { every: 17, drift: 0.25 }
        );
        let cp = CheckpointPolicy {
            every: 5,
            dir: Some("models".into()),
            name: "s".into(),
            keep_last: 3,
        };
        assert_eq!(roundtrip(&cp), cp);
    }

    fn tiny_model(n: usize, seed: u64) -> FittedModel {
        let mut rng = Rng::seed_from_u64(seed);
        let ds = dist1d(Dist1d::Uniform, n, &mut rng);
        let cfg = FitConfig::default_for(&ds);
        fit_with_backend(&ds, &cfg, Backend::Native).unwrap()
    }

    #[test]
    fn model_file_roundtrip_predicts_bitwise() {
        let model = tiny_model(150, 7);
        let bytes = encode_model(&model);
        let back = decode_model(&bytes).unwrap();
        assert_eq!(back.nystrom.idx, model.nystrom.idx);
        assert_eq!(bits(&back.nystrom.beta), bits(&model.nystrom.beta));
        assert_eq!(bits(&back.q), bits(&model.q));
        assert_eq!(back.n_train, model.n_train);
        assert_eq!(back.n_train, 150);
        let grid = Mat::from_fn(64, 1, |i, _| i as f64 / 63.0);
        assert_eq!(
            bits(&back.predict_batch(&grid)),
            bits(&model.predict_batch(&grid)),
            "loaded model must predict bit-identically"
        );
    }

    fn tiny_checkpoint(n: usize, seed: u64) -> StreamCheckpoint {
        let mut rng = Rng::seed_from_u64(seed);
        let ds = dist1d(Dist1d::Bimodal, n, &mut rng);
        let cfg = StreamConfig {
            kernel: KernelSpec::Matern { nu: 1.5, a: 1.0 },
            mu: n as f64 * 1e-3,
            budget: 16,
            accept_threshold: 0.01,
            refresh: RefreshPolicy { every: 32, drift: 0.0 },
            threads: None,
            checkpoint: CheckpointPolicy::default(),
        };
        let mut sc = crate::stream::StreamCoordinator::new(cfg);
        sc.set_origin(format!("bimodal:n={n}:seed={seed}:d=1"));
        for i in 0..ds.n() {
            sc.ingest(ds.x.row(i), ds.y[i]);
        }
        sc.checkpoint()
    }

    #[test]
    fn checkpoint_file_roundtrip_is_bitwise() {
        let chk = tiny_checkpoint(120, 8);
        let bytes = encode_checkpoint(&chk);
        let back = decode_checkpoint(&bytes).unwrap();
        assert_eq!(back.cfg.kernel, chk.cfg.kernel);
        assert_eq!(back.model.n_seen(), chk.model.n_seen());
        assert_eq!(back.model.dict().arrivals(), chk.model.dict().arrivals());
        assert_eq!(bits(back.model.beta()), bits(chk.model.beta()));
        assert_eq!(bits(&back.window), bits(&chk.window));
        assert_eq!(back.since_publish, chk.since_publish);
        assert_eq!(back.err_at_publish.to_bits(), chk.err_at_publish.to_bits());
        assert_eq!(back.origin, chk.origin);
        assert_eq!(back.origin.as_deref(), Some("bimodal:n=120:seed=8:d=1"));
        for &x in &[0.05, 0.4, 0.9] {
            assert_eq!(
                back.model.predict_one(&[x]).to_bits(),
                chk.model.predict_one(&[x]).to_bits()
            );
        }
    }

    #[test]
    fn corrupted_artifacts_are_rejected_with_typed_errors() {
        let bytes = encode_model(&tiny_model(80, 9));
        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        assert!(matches!(decode_model(&b), Err(PersistError::BadMagic)));
        // future format version
        let mut b = bytes.clone();
        b[4] = 0xFF;
        assert!(matches!(
            decode_model(&b),
            Err(PersistError::UnsupportedVersion { .. })
        ));
        // flipped payload bit → per-section CRC catches it
        let mut b = bytes.clone();
        let mid = b.len() / 2;
        b[mid] ^= 0x01;
        assert!(matches!(decode_model(&b), Err(PersistError::ChecksumMismatch { .. })));
        // truncation at every prefix length must yield a typed error,
        // never a panic or a half-decoded model
        for cut in [0, 3, 7, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            let err = decode_model(&bytes[..cut]).unwrap_err();
            assert!(err.is_corrupt(), "cut={cut}: {err}");
        }
        // wrong kind: a checkpoint is not a model
        let chk_bytes = encode_checkpoint(&tiny_checkpoint(60, 10));
        assert!(matches!(decode_model(&chk_bytes), Err(PersistError::WrongKind { .. })));
        assert!(matches!(
            decode_checkpoint(&bytes),
            Err(PersistError::WrongKind { .. })
        ));
    }

    #[test]
    fn unknown_sections_are_ignored_for_forward_compat() {
        let model = tiny_model(60, 11);
        let body = payload_of(&model);
        let extra = b"future-extension payload";
        let bytes = build_artifact(
            ArtifactKind::Model,
            &[(*b"XTRA", extra.as_slice()), (*b"MODL", body.as_slice())],
        );
        let back = decode_model(&bytes).unwrap();
        assert_eq!(bits(&back.nystrom.beta), bits(&model.nystrom.beta));
    }

    #[test]
    fn giant_section_length_in_header_fails_cleanly() {
        // a section header claiming a near-usize::MAX payload must be a
        // typed error, never overflow arithmetic or a slice panic
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        bytes.extend_from_slice(&(ArtifactKind::Model as u16).to_le_bytes());
        bytes.extend_from_slice(b"MODL");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_model(&bytes).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        bytes.truncate(bytes.len() - 8);
        bytes.extend_from_slice(&(u64::MAX - 3).to_le_bytes());
        let err = decode_model(&bytes).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
    }

    #[test]
    fn giant_claimed_lengths_fail_cleanly() {
        // a corrupt u64 length must not trigger a huge allocation
        let mut w = Writer::new();
        w.put_u64(u64::MAX);
        let mut r = Reader::new(&w.buf);
        assert!(Vec::<f64>::decode(&mut r).is_err());
        let mut w = Writer::new();
        w.put_u64(1 << 40);
        w.put_u64(1 << 40);
        let mut r = Reader::new(&w.buf);
        assert!(Mat::decode(&mut r).is_err());
    }
}
