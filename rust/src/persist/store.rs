//! Versioned on-disk artifact store.
//!
//! Layout under a root directory:
//!
//! ```text
//!   <root>/<name>/<version>.lkrr     one artifact per monotonically
//!                                    increasing integer version
//!   <root>/<name>/MANIFEST.json      provenance: name, version, kind,
//!                                    created-at, n/m/d, kernel, checksum
//! ```
//!
//! Writes are crash-safe: the artifact lands in a dot-prefixed temp file
//! first and is moved into place with an atomic `rename`, so a reader
//! never observes a half-written `.lkrr` file (the manifest is rewritten
//! the same way). The manifest is advisory — `load` decodes and
//! CRC-verifies the artifact itself, so a lost or stale manifest only
//! costs metadata, never correctness.
//!
//! Any corrupt artifact (bad magic, wrong format version, checksum
//! mismatch, truncation, malformed payload) is rejected with the typed
//! [`PersistError`] and counted in `metrics::global()` under
//! `persist.load.corrupt` — a loader never panics and never yields a
//! half-decoded model.

use super::codec::{self, ArtifactKind};
use super::PersistError;
use crate::coordinator::FittedModel;
use crate::stream::StreamCheckpoint;
use crate::trace;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Manifest entry for one stored artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub version: u64,
    /// `"model"` or `"checkpoint"`.
    pub kind: String,
    /// Unix seconds at save time.
    pub created_unix: u64,
    /// Training points the artifact has seen (batch n or stream n_seen).
    pub n: u64,
    /// Landmarks / dictionary atoms.
    pub m: u64,
    /// Input dimension.
    pub d: u64,
    /// Kernel spec string, e.g. `matern(nu=1.5,a=1.732)`.
    pub kernel: String,
    /// Artifact file size in bytes.
    pub bytes: u64,
    /// CRC32 of the complete artifact file.
    pub checksum: u32,
}

impl ArtifactMeta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("version", Json::Num(self.version as f64)),
            ("kind", Json::Str(self.kind.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("n", Json::Num(self.n as f64)),
            ("m", Json::Num(self.m as f64)),
            ("d", Json::Num(self.d as f64)),
            ("kernel", Json::Str(self.kernel.clone())),
            ("bytes", Json::Num(self.bytes as f64)),
            ("checksum", Json::Num(self.checksum as f64)),
        ])
    }

    fn from_json(j: &Json) -> Option<ArtifactMeta> {
        Some(ArtifactMeta {
            name: j.get("name").as_str()?.to_string(),
            version: j.get("version").as_usize()? as u64,
            kind: j.get("kind").as_str().unwrap_or("model").to_string(),
            created_unix: j.get("created_unix").as_usize().unwrap_or(0) as u64,
            n: j.get("n").as_usize().unwrap_or(0) as u64,
            m: j.get("m").as_usize().unwrap_or(0) as u64,
            d: j.get("d").as_usize().unwrap_or(0) as u64,
            kernel: j.get("kernel").as_str().unwrap_or("?").to_string(),
            bytes: j.get("bytes").as_usize().unwrap_or(0) as u64,
            checksum: j.get("checksum").as_usize().unwrap_or(0) as u32,
        })
    }
}

/// Handle to an artifact-store root directory.
#[derive(Clone, Debug)]
pub struct Store {
    root: PathBuf,
}

/// Process-wide sequence making every temp-file name unique: concurrent
/// same-process writers (which the version-claim loop in `save_bytes`
/// explicitly supports) must not truncate each other's temp files —
/// the pid alone cannot distinguish two threads.
fn unique_tmp_name(prefix: &str) -> String {
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    format!(
        ".tmp-{prefix}-{}-{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    )
}

impl Store {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Store, PersistError> {
        std::fs::create_dir_all(&dir)?;
        Ok(Store { root: dir.as_ref().to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn name_dir(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// On-disk path of one artifact version.
    pub fn path_of(&self, name: &str, version: u64) -> PathBuf {
        self.name_dir(name).join(format!("{version}.lkrr"))
    }

    fn manifest_path(&self, name: &str) -> PathBuf {
        self.name_dir(name).join("MANIFEST.json")
    }

    fn check_name(name: &str) -> Result<(), PersistError> {
        let ok = !name.is_empty()
            && name != "."
            && name != ".."
            && !name.starts_with('.')
            && name.chars().all(|c| c.is_ascii_alphanumeric() || "-_.".contains(c));
        if ok {
            Ok(())
        } else {
            Err(PersistError::Malformed(format!("bad artifact name '{name}'")))
        }
    }

    /// Stored versions of `name`, ascending (empty if none or the name
    /// is invalid — every name-taking entry point rejects path-escaping
    /// names like `../x`, not just `save`).
    pub fn versions(&self, name: &str) -> Vec<u64> {
        if Self::check_name(name).is_err() {
            return Vec::new();
        }
        let mut vs: Vec<u64> = match std::fs::read_dir(self.name_dir(name)) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    let fname = e.file_name().into_string().ok()?;
                    fname.strip_suffix(".lkrr")?.parse::<u64>().ok()
                })
                .collect(),
            Err(_) => Vec::new(),
        };
        vs.sort_unstable();
        vs
    }

    /// Highest stored version of `name` (None if absent).
    pub fn latest(&self, name: &str) -> Option<u64> {
        self.versions(name).last().copied()
    }

    /// Artifact names present in the store, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = match std::fs::read_dir(&self.root) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter(|e| e.path().is_dir())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter(|n| !n.starts_with('.'))
                .collect(),
            Err(_) => Vec::new(),
        };
        out.sort();
        out
    }

    fn read_manifest(&self, name: &str) -> Vec<ArtifactMeta> {
        let Ok(text) = std::fs::read_to_string(self.manifest_path(name)) else {
            return Vec::new();
        };
        let Ok(doc) = Json::parse(&text) else { return Vec::new() };
        doc.get("artifacts")
            .as_arr()
            .map(|a| a.iter().filter_map(ArtifactMeta::from_json).collect())
            .unwrap_or_default()
    }

    fn write_manifest(&self, name: &str, entries: &[ArtifactMeta]) -> Result<(), PersistError> {
        let doc = Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("artifacts", Json::Arr(entries.iter().map(|e| e.to_json()).collect())),
        ]);
        let tmp = self.name_dir(name).join(unique_tmp_name("manifest"));
        std::fs::write(&tmp, doc.to_string_pretty())?;
        std::fs::rename(&tmp, self.manifest_path(name))?;
        Ok(())
    }

    /// Manifest entries for every artifact under every name (or one name
    /// with [`Store::list_name`]). Versions present on disk but missing
    /// from a manifest get a minimal synthesized entry.
    pub fn list(&self) -> Vec<ArtifactMeta> {
        self.names().iter().flat_map(|n| self.list_name(n)).collect()
    }

    /// Manifest entries for one artifact name, ascending by version.
    pub fn list_name(&self, name: &str) -> Vec<ArtifactMeta> {
        if Self::check_name(name).is_err() {
            return Vec::new();
        }
        let manifest = self.read_manifest(name);
        let mut out: Vec<ArtifactMeta> = Vec::new();
        for v in self.versions(name) {
            match manifest.iter().find(|e| e.version == v) {
                Some(e) => out.push(e.clone()),
                None => out.push(ArtifactMeta {
                    name: name.to_string(),
                    version: v,
                    kind: "?".to_string(),
                    created_unix: 0,
                    n: 0,
                    m: 0,
                    d: 0,
                    kernel: "?".to_string(),
                    bytes: std::fs::metadata(self.path_of(name, v))
                        .map(|m| m.len())
                        .unwrap_or(0),
                    checksum: 0,
                }),
            }
        }
        out
    }

    fn save_bytes(
        &self,
        name: &str,
        kind: ArtifactKind,
        bytes: &[u8],
        n: u64,
        m: u64,
        d: u64,
        kernel: String,
    ) -> Result<ArtifactMeta, PersistError> {
        Self::check_name(name)?;
        std::fs::create_dir_all(self.name_dir(name))?;
        // temp file first: a concurrent reader either sees the previous
        // version set or a complete new file, never a prefix (the
        // sequence counter keeps same-process writers from colliding)
        let tmp = self.name_dir(name).join(unique_tmp_name("artifact"));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            // data must hit disk before the link becomes durable —
            // otherwise a power cut can leave a complete-looking but
            // empty/partial file as the latest version
            f.sync_all()?;
        }
        // claim a version slot with hard_link, which (unlike rename)
        // fails if the destination exists: two writers racing on
        // latest()+1 get distinct versions instead of one silently
        // overwriting the other's artifact
        let mut version = self.latest(name).map_or(1, |v| v + 1);
        let mut claimed = false;
        for _ in 0..64 {
            match std::fs::hard_link(&tmp, self.path_of(name, version)) {
                Ok(()) => {
                    claimed = true;
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => version += 1,
                Err(e) => {
                    let _ = std::fs::remove_file(&tmp);
                    return Err(PersistError::Io(e));
                }
            }
        }
        let _ = std::fs::remove_file(&tmp);
        if !claimed {
            return Err(PersistError::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                "could not claim an artifact version slot (64 contended attempts)",
            )));
        }
        // best-effort directory sync so the link itself survives a crash
        if let Ok(d) = std::fs::File::open(self.name_dir(name)) {
            let _ = d.sync_all();
        }
        let created_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let meta = ArtifactMeta {
            name: name.to_string(),
            version,
            kind: kind.name().to_string(),
            created_unix,
            n,
            m,
            d,
            kernel,
            bytes: bytes.len() as u64,
            checksum: codec::crc32(bytes),
        };
        let mut entries = self.read_manifest(name);
        entries.retain(|e| e.version != version);
        entries.push(meta.clone());
        entries.sort_by_key(|e| e.version);
        self.write_manifest(name, &entries)?;
        Ok(meta)
    }

    /// Save a fitted model; returns its manifest entry (with the new
    /// version).
    pub fn save_model(&self, name: &str, model: &FittedModel) -> Result<ArtifactMeta, PersistError> {
        let _span = trace::span("persist.save_model");
        let bytes = codec::encode_model(model);
        self.save_bytes(
            name,
            ArtifactKind::Model,
            &bytes,
            model.n_train,
            model.nystrom.m() as u64,
            model.nystrom.landmarks.cols as u64,
            model.nystrom.kernel.spec.name(),
        )
    }

    /// Save a stream checkpoint; returns its manifest entry.
    pub fn save_checkpoint(
        &self,
        name: &str,
        chk: &StreamCheckpoint,
    ) -> Result<ArtifactMeta, PersistError> {
        let _span = trace::span("persist.save_checkpoint");
        let bytes = codec::encode_checkpoint(chk);
        self.save_bytes(
            name,
            ArtifactKind::Checkpoint,
            &bytes,
            chk.model.n_seen(),
            chk.model.m() as u64,
            chk.model.dict().dim() as u64,
            chk.cfg.kernel.name(),
        )
    }

    /// Read raw artifact bytes (latest version when `version` is None),
    /// verifying the whole-file checksum against the manifest when an
    /// entry exists.
    /// Callers (`load_model` / `load_checkpoint`) have already validated
    /// `name` — outside the corrupt-counting wrapper, since a bad name is
    /// a caller error, not a damaged artifact.
    fn load_bytes(&self, name: &str, version: Option<u64>) -> Result<(u64, Vec<u8>), PersistError> {
        let v = match version.or_else(|| self.latest(name)) {
            Some(v) => v,
            None => return Err(PersistError::NotFound { name: name.to_string(), version }),
        };
        let path = self.path_of(name, v);
        let bytes = std::fs::read(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                PersistError::NotFound { name: name.to_string(), version: Some(v) }
            } else {
                PersistError::Io(e)
            }
        })?;
        if let Some(entry) = self.read_manifest(name).iter().find(|e| e.version == v) {
            if entry.checksum != 0 && entry.checksum != codec::crc32(&bytes) {
                return Err(PersistError::ChecksumMismatch { section: "file".to_string() });
            }
        }
        Ok((v, bytes))
    }

    /// Count a corrupt reject in the process-global metrics registry.
    fn reject_if_corrupt<T>(res: Result<T, PersistError>) -> Result<T, PersistError> {
        if let Err(e) = &res {
            if e.is_corrupt() {
                crate::metrics::global().incr("persist.load.corrupt", 1);
            }
        }
        res
    }

    /// Load a model (latest version when `version` is None). Corrupt
    /// artifacts yield a typed error and a `persist.load.corrupt` count.
    pub fn load_model(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> Result<(u64, FittedModel), PersistError> {
        let _span = trace::span("persist.load_model");
        Self::check_name(name)?;
        Self::reject_if_corrupt(
            self.load_bytes(name, version)
                .and_then(|(v, bytes)| Ok((v, codec::decode_model(&bytes)?))),
        )
    }

    /// Load a stream checkpoint (latest version when `version` is None).
    pub fn load_checkpoint(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> Result<(u64, StreamCheckpoint), PersistError> {
        let _span = trace::span("persist.load_checkpoint");
        Self::check_name(name)?;
        Self::reject_if_corrupt(
            self.load_bytes(name, version)
                .and_then(|(v, bytes)| Ok((v, codec::decode_checkpoint(&bytes)?))),
        )
    }

    /// Drop all but the newest `keep_last` versions of `name`; returns
    /// how many artifacts were removed. `keep_last == 0` keeps everything.
    pub fn gc(&self, name: &str, keep_last: usize) -> Result<usize, PersistError> {
        Self::check_name(name)?;
        let versions = self.versions(name);
        if keep_last == 0 || versions.len() <= keep_last {
            return Ok(0);
        }
        let cut = versions.len() - keep_last;
        let drop: Vec<u64> = versions[..cut].to_vec();
        for &v in &drop {
            std::fs::remove_file(self.path_of(name, v))?;
        }
        let mut entries = self.read_manifest(name);
        entries.retain(|e| !drop.contains(&e.version));
        self.write_manifest(name, &entries)?;
        Ok(drop.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_with_backend, FitConfig};
    use crate::data::{dist1d, Dist1d};
    use crate::linalg::Mat;
    use crate::runtime::Backend;
    use crate::util::rng::Rng;

    /// Fresh store under the OS temp dir, removed on drop.
    struct TempStore {
        store: Store,
        dir: PathBuf,
    }

    impl TempStore {
        fn new(tag: &str) -> TempStore {
            let dir = std::env::temp_dir().join(format!(
                "leverkrr-store-test-{tag}-{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            TempStore { store: Store::open(&dir).unwrap(), dir }
        }
    }

    impl Drop for TempStore {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }

    fn tiny_model(seed: u64) -> FittedModel {
        let mut rng = Rng::seed_from_u64(seed);
        let ds = dist1d(Dist1d::Uniform, 120, &mut rng);
        let cfg = FitConfig::default_for(&ds);
        fit_with_backend(&ds, &cfg, Backend::Native).unwrap()
    }

    #[test]
    fn save_load_roundtrip_and_versioning() {
        let ts = TempStore::new("roundtrip");
        let m1 = tiny_model(1);
        let meta1 = ts.store.save_model("demo", &m1).unwrap();
        assert_eq!(meta1.version, 1);
        assert_eq!(meta1.kind, "model");
        assert_eq!(meta1.m, m1.nystrom.m() as u64);
        let m2 = tiny_model(2);
        let meta2 = ts.store.save_model("demo", &m2).unwrap();
        assert_eq!(meta2.version, 2);
        assert_eq!(ts.store.versions("demo"), vec![1, 2]);
        assert_eq!(ts.store.latest("demo"), Some(2));
        // latest loads v2, explicit version loads v1 — both bitwise
        let (v, loaded2) = ts.store.load_model("demo", None).unwrap();
        assert_eq!(v, 2);
        assert_eq!(loaded2.nystrom.beta, m2.nystrom.beta);
        let (_, loaded1) = ts.store.load_model("demo", Some(1)).unwrap();
        assert_eq!(loaded1.nystrom.beta, m1.nystrom.beta);
        let grid = Mat::from_fn(32, 1, |i, _| i as f64 / 31.0);
        let want: Vec<u64> = m2.predict_batch(&grid).iter().map(|x| x.to_bits()).collect();
        let got: Vec<u64> =
            loaded2.predict_batch(&grid).iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
        // no temp files left behind
        let leftovers: Vec<_> = std::fs::read_dir(ts.dir.join("demo"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
    }

    #[test]
    fn manifest_lists_provenance() {
        let ts = TempStore::new("manifest");
        ts.store.save_model("a", &tiny_model(3)).unwrap();
        ts.store.save_model("a", &tiny_model(4)).unwrap();
        ts.store.save_model("b", &tiny_model(5)).unwrap();
        let all = ts.store.list();
        assert_eq!(all.len(), 3);
        let a = ts.store.list_name("a");
        assert_eq!(a.len(), 2);
        assert_eq!((a[0].version, a[1].version), (1, 2));
        assert!(a[0].kernel.starts_with("matern"));
        assert!(a[0].bytes > 0 && a[0].checksum != 0);
        assert_eq!(a[0].n, 120);
        assert_eq!(a[0].d, 1);
    }

    #[test]
    fn gc_keeps_newest_k() {
        let ts = TempStore::new("gc");
        for s in 0..5 {
            ts.store.save_model("demo", &tiny_model(s)).unwrap();
        }
        let removed = ts.store.gc("demo", 2).unwrap();
        assert_eq!(removed, 3);
        assert_eq!(ts.store.versions("demo"), vec![4, 5]);
        assert_eq!(ts.store.list_name("demo").len(), 2);
        // keep_last 0 keeps everything
        assert_eq!(ts.store.gc("demo", 0).unwrap(), 0);
        // latest still loads
        assert_eq!(ts.store.load_model("demo", None).unwrap().0, 5);
    }

    #[test]
    fn missing_artifacts_are_not_found() {
        let ts = TempStore::new("missing");
        assert!(matches!(
            ts.store.load_model("nope", None),
            Err(PersistError::NotFound { .. })
        ));
        ts.store.save_model("demo", &tiny_model(6)).unwrap();
        assert!(matches!(
            ts.store.load_model("demo", Some(9)),
            Err(PersistError::NotFound { .. })
        ));
    }

    #[test]
    fn corrupt_artifact_rejected_and_counted() {
        let ts = TempStore::new("corrupt");
        let meta = ts.store.save_model("demo", &tiny_model(7)).unwrap();
        let path = ts.store.path_of("demo", meta.version);
        // flip one payload bit on disk
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let before = crate::metrics::global().counter("persist.load.corrupt");
        let err = ts.store.load_model("demo", None).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        assert!(matches!(err, PersistError::ChecksumMismatch { .. }));
        // truncation is also typed + counted
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        let err = ts.store.load_model("demo", None).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        assert_eq!(
            crate::metrics::global().counter("persist.load.corrupt"),
            before + 2,
            "corrupt rejects must be counted in metrics::global()"
        );
    }

    #[test]
    fn bad_names_rejected() {
        let ts = TempStore::new("names");
        let m = tiny_model(8);
        for bad in ["", ".", "..", "a/b", "../escape", ".hidden", "x y"] {
            assert!(
                matches!(ts.store.save_model(bad, &m), Err(PersistError::Malformed(_))),
                "save with name '{bad}' must be rejected"
            );
            assert!(
                matches!(ts.store.load_model(bad, None), Err(PersistError::Malformed(_))),
                "load with name '{bad}' must be rejected"
            );
            assert!(
                matches!(ts.store.gc(bad, 1), Err(PersistError::Malformed(_))),
                "gc with name '{bad}' must be rejected"
            );
            assert!(ts.store.versions(bad).is_empty());
            assert!(ts.store.list_name(bad).is_empty());
        }
    }

    #[test]
    fn checkpoint_save_load_roundtrip() {
        use crate::kernels::KernelSpec;
        use crate::stream::{CheckpointPolicy, RefreshPolicy, StreamConfig, StreamCoordinator};
        let ts = TempStore::new("ckpt");
        let mut rng = Rng::seed_from_u64(9);
        let ds = dist1d(Dist1d::Bimodal, 150, &mut rng);
        let cfg = StreamConfig {
            kernel: KernelSpec::Matern { nu: 1.5, a: 1.0 },
            mu: 0.15,
            budget: 16,
            accept_threshold: 0.01,
            refresh: RefreshPolicy { every: 32, drift: 0.0 },
            threads: None,
            checkpoint: CheckpointPolicy::default(),
        };
        let mut sc = StreamCoordinator::new(cfg);
        for i in 0..ds.n() {
            sc.ingest(ds.x.row(i), ds.y[i]);
        }
        let chk = sc.checkpoint();
        let meta = ts.store.save_checkpoint("stream", &chk).unwrap();
        assert_eq!(meta.kind, "checkpoint");
        assert_eq!(meta.n, 150);
        let (_, back) = ts.store.load_checkpoint("stream", None).unwrap();
        assert_eq!(back.model.beta(), chk.model.beta());
        // a checkpoint is not a model
        assert!(matches!(
            ts.store.load_model("stream", None),
            Err(PersistError::WrongKind { .. })
        ));
    }
}
