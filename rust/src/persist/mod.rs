//! Model persistence: binary codec + versioned artifact store.
//!
//! Everything the crate can fit or stream — the SA-leverage Nyström
//! model ([`crate::coordinator::FittedModel`]), the streaming
//! dictionary / incremental model, a full
//! [`crate::stream::StreamCoordinator`] checkpoint — can be frozen to a
//! compact binary artifact and brought back **bit-identically**: a
//! loaded model predicts the same bits as the fitted one, and a restored
//! checkpoint replays subsequent arrivals to the same bits as an
//! uninterrupted run (the same determinism contract the compute pool
//! pins across thread counts).
//!
//! * [`codec`] — dependency-free binary format: `LKRR` magic +
//!   format-version header, length-prefixed CRC32-verified sections,
//!   `f64`s stored as exact bit patterns. [`codec::Encode`] /
//!   [`codec::Decode`] cover `Mat`, `Cholesky`, kernels, the fitted
//!   model, the online dictionary, the incremental model, and stream
//!   checkpoints.
//! * [`store`] — `<dir>/<name>/<version>.lkrr` with a JSON `MANIFEST`
//!   (name, version, kind, created-at, n/m/d, kernel, checksum); writes
//!   are temp-file + atomic rename; `save` / `load` / `list` / `latest`
//!   / `gc(keep_last_k)`.
//!
//! Corruption anywhere (bit flip, truncation, foreign file, newer
//! format) is a typed [`PersistError`] — never a panic, never a
//! half-decoded model — and every corrupt reject is counted in
//! [`crate::metrics::global`] as `persist.load.corrupt`.
//!
//! Wiring through the stack: `FittedModel::{save, load}`,
//! [`crate::coordinator::Server::start_from_artifact`] (cold start a
//! serving process with zero refit work),
//! `StreamCoordinator::{checkpoint, restore}` plus the periodic
//! [`crate::stream::CheckpointPolicy`], the `export` / `import` /
//! `models` CLI subcommands, `stream --warm-start`, and the `persist`
//! JSON config section.

pub mod codec;
pub mod store;

pub use codec::{ArtifactKind, Decode, Encode, FORMAT_VERSION, MAGIC};
pub use store::{ArtifactMeta, Store};

/// Typed persistence failure. `is_corrupt` distinguishes damaged or
/// foreign artifacts (counted as `persist.load.corrupt`) from plain I/O
/// or lookup errors.
#[derive(Debug)]
pub enum PersistError {
    Io(std::io::Error),
    /// The file does not start with the `LKRR` magic.
    BadMagic,
    /// Written by a newer (or invalid) format version.
    UnsupportedVersion { found: u16 },
    /// The artifact holds a different kind than requested (e.g. loading
    /// a stream checkpoint as a model).
    WrongKind { expected: ArtifactKind, found: ArtifactKind },
    /// A section's CRC32 does not match its payload (`section` is the
    /// tag, or `"file"` for a whole-file checksum from the manifest).
    ChecksumMismatch { section: String },
    /// The file ends mid-header, mid-section, or mid-value.
    Truncated,
    /// Structurally invalid payload (bad tag, arity mismatch, …).
    Malformed(String),
    /// No such artifact name/version in the store.
    NotFound { name: String, version: Option<u64> },
}

impl PersistError {
    /// True for damaged/foreign-artifact rejects — the class counted
    /// under `persist.load.corrupt` (I/O and not-found are not corruption).
    pub fn is_corrupt(&self) -> bool {
        matches!(
            self,
            PersistError::BadMagic
                | PersistError::UnsupportedVersion { .. }
                | PersistError::WrongKind { .. }
                | PersistError::ChecksumMismatch { .. }
                | PersistError::Truncated
                | PersistError::Malformed(_)
        )
    }
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "persist i/o: {e}"),
            PersistError::BadMagic => write!(f, "not a leverkrr artifact (bad magic)"),
            PersistError::UnsupportedVersion { found } => {
                write!(f, "unsupported artifact format version {found} (reader supports ≤ {})", codec::FORMAT_VERSION)
            }
            PersistError::WrongKind { expected, found } => {
                write!(f, "artifact kind mismatch: expected {}, found {}", expected.name(), found.name())
            }
            PersistError::ChecksumMismatch { section } => {
                write!(f, "checksum mismatch in section '{section}' (artifact corrupted)")
            }
            PersistError::Truncated => write!(f, "artifact truncated"),
            PersistError::Malformed(msg) => write!(f, "malformed artifact: {msg}"),
            PersistError::NotFound { name, version } => match version {
                Some(v) => write!(f, "artifact '{name}' version {v} not found"),
                None => write!(f, "artifact '{name}' not found"),
            },
        }
    }
}

impl std::error::Error for PersistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PersistError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        PersistError::Io(e)
    }
}
