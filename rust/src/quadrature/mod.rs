//! Numerical integration: Gauss–Legendre rules, adaptive Simpson, and
//! semi-infinite transforms.
//!
//! Used by the SA leverage estimator's quadrature path (the polar-reduced
//! integral of Eqn 6, Appendix D of the paper), the polylogarithm
//! (Fermi–Dirac integral), and the general-ν Bessel K_ν integral
//! representation.

/// Gauss–Legendre nodes/weights on [-1, 1], computed once per order via
/// Newton iteration on P_n (Golub–Welsch-free; fine for n ≤ ~200).
#[derive(Clone, Debug)]
pub struct GaussLegendre {
    pub nodes: Vec<f64>,
    pub weights: Vec<f64>,
}

impl GaussLegendre {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let mut nodes = vec![0.0; n];
        let mut weights = vec![0.0; n];
        let m = n.div_ceil(2);
        for i in 0..m {
            // Chebyshev-like initial guess
            let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
            let mut dp = 0.0;
            for _ in 0..100 {
                let (p, d) = legendre_pd(n, x);
                dp = d;
                let dx = p / d;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes[i] = -x;
            nodes[n - 1 - i] = x;
            let w = 2.0 / ((1.0 - x * x) * dp * dp);
            weights[i] = w;
            weights[n - 1 - i] = w;
        }
        GaussLegendre { nodes, weights }
    }

    /// ∫_a^b f(x) dx with this rule.
    pub fn integrate(&self, a: f64, b: f64, mut f: impl FnMut(f64) -> f64) -> f64 {
        let c = 0.5 * (b - a);
        let d = 0.5 * (b + a);
        let mut s = 0.0;
        for (x, w) in self.nodes.iter().zip(&self.weights) {
            s += w * f(c * x + d);
        }
        c * s
    }
}

/// P_n(x) and P_n'(x) via the three-term recurrence.
fn legendre_pd(n: usize, x: f64) -> (f64, f64) {
    let mut p0 = 1.0;
    let mut p1 = x;
    if n == 0 {
        return (1.0, 0.0);
    }
    for k in 2..=n {
        let kf = k as f64;
        let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
        p0 = p1;
        p1 = p2;
    }
    let d = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, d)
}

/// Adaptive Simpson on [a, b] to absolute/relative tolerance.
pub fn adaptive_simpson(f: &impl Fn(f64) -> f64, a: f64, b: f64, tol: f64) -> f64 {
    let fa = f(a);
    let fb = f(b);
    let m = 0.5 * (a + b);
    let fm = f(m);
    let whole = (b - a) / 6.0 * (fa + 4.0 * fm + fb);
    simpson_rec(f, a, b, fa, fb, fm, whole, tol, 40)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec(
    f: &impl Fn(f64) -> f64,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fm: f64,
    whole: f64,
    tol: f64,
    depth: usize,
) -> f64 {
    let m = 0.5 * (a + b);
    let lm = 0.5 * (a + m);
    let rm = 0.5 * (m + b);
    let flm = f(lm);
    let frm = f(rm);
    let left = (m - a) / 6.0 * (fa + 4.0 * flm + fm);
    let right = (b - m) / 6.0 * (fm + 4.0 * frm + fb);
    let delta = left + right - whole;
    if depth == 0 || delta.abs() <= 15.0 * tol {
        left + right + delta / 15.0
    } else {
        simpson_rec(f, a, m, fa, fm, flm, left, 0.5 * tol, depth - 1)
            + simpson_rec(f, m, b, fm, fb, frm, right, 0.5 * tol, depth - 1)
    }
}

/// ∫_0^∞ f(x) dx via x = t/(1−t) with adaptive Simpson on (0,1).
///
/// Integrand must decay at ∞ (all our uses are ≲ x^{d−1}/(p+λx^{2α}) with
/// 2α > d, or exponentially decaying).
pub fn integrate_semi_infinite(f: impl Fn(f64) -> f64, tol: f64) -> f64 {
    let g = |t: f64| {
        if t <= 0.0 || t >= 1.0 {
            return 0.0;
        }
        let om = 1.0 - t;
        let x = t / om;
        let v = f(x) / (om * om);
        if v.is_finite() {
            v
        } else {
            0.0
        }
    };
    adaptive_simpson(&g, 0.0, 1.0, tol)
}

/// ∫_0^∞ f with a fixed-order Gauss–Legendre panel scheme: integrates
/// [0, x0], then geometric panels [x0·2^k, x0·2^{k+1}] until the panel
/// contribution is negligible. Faster than the adaptive path when f is
/// smooth; used in the SA hot loop.
pub fn integrate_semi_infinite_panels(
    gl: &GaussLegendre,
    x0: f64,
    f: impl Fn(f64) -> f64 + Copy,
    rel_tol: f64,
    max_panels: usize,
) -> f64 {
    let mut total = gl.integrate(0.0, x0, f);
    let mut lo = x0;
    for _ in 0..max_panels {
        let hi = lo * 2.0;
        let panel = gl.integrate(lo, hi, f);
        total += panel;
        if panel.abs() <= rel_tol * total.abs().max(1e-300) {
            break;
        }
        lo = hi;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn gl_nodes_symmetric_weights_sum_to_2() {
        for &n in &[1usize, 2, 5, 16, 64] {
            let gl = GaussLegendre::new(n);
            let ws: f64 = gl.weights.iter().sum();
            assert!((ws - 2.0).abs() < 1e-12, "n={n} ws={ws}");
            for i in 0..n {
                assert!((gl.nodes[i] + gl.nodes[n - 1 - i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gl_exact_for_polynomials() {
        // order-n GL is exact for degree ≤ 2n−1
        let gl = GaussLegendre::new(5);
        for deg in 0..=9usize {
            let got = gl.integrate(-1.0, 1.0, |x| x.powi(deg as i32));
            let want = if deg % 2 == 1 { 0.0 } else { 2.0 / (deg as f64 + 1.0) };
            assert!((got - want).abs() < 1e-12, "deg={deg}");
        }
    }

    #[test]
    fn gl_integrates_sin() {
        let gl = GaussLegendre::new(24);
        let got = gl.integrate(0.0, PI, f64::sin);
        assert!((got - 2.0).abs() < 1e-12);
    }

    #[test]
    fn adaptive_simpson_handles_peaks() {
        // ∫_0^1 1/sqrt(x) dx = 2 (integrable singularity at 0 — start just above)
        let got = adaptive_simpson(&|x: f64| 1.0 / x.max(1e-14).sqrt(), 1e-12, 1.0, 1e-9);
        assert!((got - 2.0).abs() < 1e-3, "got {got}");
        // smooth case to tight tolerance
        let got = adaptive_simpson(&|x: f64| (-x * x).exp(), 0.0, 3.0, 1e-12);
        let want = 0.5 * PI.sqrt() * crate::special::erf(3.0);
        assert!((got - want).abs() < 1e-10);
    }

    #[test]
    fn semi_infinite_gaussian() {
        // ∫_0^∞ e^{-x²} dx = √π/2
        let got = integrate_semi_infinite(|x| (-x * x).exp(), 1e-12);
        assert!((got - 0.5 * PI.sqrt()).abs() < 1e-8, "got {got}");
    }

    #[test]
    fn semi_infinite_rational() {
        // ∫_0^∞ dx/(1+x²) = π/2  — the shape of the SA integrand.
        let got = integrate_semi_infinite(|x| 1.0 / (1.0 + x * x), 1e-12);
        assert!((got - 0.5 * PI).abs() < 1e-8);
    }

    #[test]
    fn panel_scheme_matches_adaptive() {
        let gl = GaussLegendre::new(32);
        for &(p, lam, alpha, d) in
            &[(1.0, 0.01, 2.0, 3.0), (0.2, 1e-4, 1.5, 1.0), (3.0, 1e-3, 4.0, 3.0)]
        {
            let f = move |r: f64| r.powf(d - 1.0) / (p + lam * (1.0 + r * r).powf(alpha));
            let a = integrate_semi_infinite(f, 1e-11);
            let b = integrate_semi_infinite_panels(&gl, (p / lam).powf(0.5 / alpha), f, 1e-12, 80);
            assert!(
                (a - b).abs() < 1e-5 * a.abs().max(1.0),
                "p={p} lam={lam}: adaptive={a} panels={b}"
            );
        }
    }
}
