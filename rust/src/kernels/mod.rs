//! Stationary kernels and their spectral densities.
//!
//! The paper works with Matérn kernels
//!   C_ν(r) = 2^{1−ν}/Γ(ν) · (a r)^ν · K_ν(a r),   a > 0,
//! (half-integer ν uses closed forms; general ν falls back to the Bessel
//! integral in [`crate::special`]) and Gaussian kernels
//!   K(r) = exp(−r² / (2σ²)).
//!
//! Spectral densities enter the SA leverage formula (Eqn 6). With the
//! paper's simplification C_α = D_α = 1 (App. A.1) the Matérn α = ν + d/2
//! spectral density is m_α(s) = (1 + ‖s‖²)^{−α}; the Gaussian one is
//! m(s) = (2πσ²)^{d/2}·e^{−2π²σ²‖s‖²} (only its shape matters: the SA
//! scores are normalized).
//!
//! The native assembly functions here are the *fallback / oracle* path;
//! the production path assembles kernel blocks through the AOT-compiled
//! Pallas artifacts (see [`crate::runtime`]) and is validated against
//! these to 1e-5.

use crate::linalg::{sqdist, Mat};
use crate::special::{bessel_k, lgamma};

/// Serializable kernel description (config-level).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelSpec {
    /// Matérn with smoothness ν and inverse length-scale a (K(r)=C_ν(a r)).
    Matern { nu: f64, a: f64 },
    /// Gaussian exp(−r²/(2σ²)).
    Gaussian { sigma: f64 },
}

impl KernelSpec {
    /// Parse "matern:nu=1.5,a=1.0" / "gaussian:sigma=0.5" CLI syntax.
    pub fn parse(s: &str) -> Result<KernelSpec, String> {
        let (name, rest) = s.split_once(':').unwrap_or((s, ""));
        let mut kv = std::collections::BTreeMap::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("bad kernel param '{part}'"))?;
            kv.insert(k.trim(), v.trim().parse::<f64>().map_err(|e| e.to_string())?);
        }
        match name {
            "matern" => Ok(KernelSpec::Matern {
                nu: *kv.get("nu").unwrap_or(&1.5),
                a: *kv.get("a").unwrap_or(&1.0),
            }),
            "gaussian" => Ok(KernelSpec::Gaussian { sigma: *kv.get("sigma").unwrap_or(&1.0) }),
            _ => Err(format!("unknown kernel '{name}' (matern|gaussian)")),
        }
    }

    pub fn build(self) -> Kernel {
        Kernel::new(self)
    }

    /// α = ν + d/2, the Sobolev smoothness of the Matérn RKHS (paper §3.1).
    pub fn alpha(&self, d: usize) -> f64 {
        match self {
            KernelSpec::Matern { nu, .. } => nu + d as f64 / 2.0,
            // Gaussian: the paper (App. C.2) treats σ via an "equivalent α";
            // callers use the polylog path instead of α for SA.
            KernelSpec::Gaussian { .. } => f64::INFINITY,
        }
    }

    pub fn name(&self) -> String {
        match self {
            KernelSpec::Matern { nu, a } => format!("matern(nu={nu},a={a})"),
            KernelSpec::Gaussian { sigma } => format!("gaussian(sigma={sigma})"),
        }
    }
}

/// A concrete kernel with fast evaluation paths.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub spec: KernelSpec,
    /// Precomputed 2^{1−ν}/Γ(ν) for the general-ν Matérn path.
    matern_norm: f64,
}

impl Kernel {
    pub fn new(spec: KernelSpec) -> Kernel {
        let matern_norm = match spec {
            KernelSpec::Matern { nu, .. } => {
                ((1.0 - nu) * std::f64::consts::LN_2 - lgamma(nu)).exp()
            }
            _ => 0.0,
        };
        Kernel { spec, matern_norm }
    }

    /// k(x, y) from the squared distance r² (all kernels are isotropic, so
    /// assembly only ever computes r² — this avoids n·m sqrt calls for the
    /// Gaussian and lets the Pallas kernel share the distance Gram).
    #[inline]
    pub fn eval_sq(&self, r2: f64) -> f64 {
        match self.spec {
            KernelSpec::Matern { nu, a } => {
                let r = r2.max(0.0).sqrt();
                let t = a * r;
                if t <= 1e-12 {
                    return 1.0;
                }
                // Half-integer closed forms (ν = ½, 3⁄2, 5⁄2) — the cases the
                // paper's experiments use and the Pallas kernels implement.
                if (nu - 0.5).abs() < 1e-12 {
                    (-t).exp()
                } else if (nu - 1.5).abs() < 1e-12 {
                    (1.0 + t) * (-t).exp()
                } else if (nu - 2.5).abs() < 1e-12 {
                    (1.0 + t + t * t / 3.0) * (-t).exp()
                } else {
                    // general ν: 2^{1−ν}/Γ(ν) t^ν K_ν(t)
                    self.matern_norm * t.powf(nu) * bessel_k(nu, t)
                }
            }
            KernelSpec::Gaussian { sigma } => (-r2 / (2.0 * sigma * sigma)).exp(),
        }
    }

    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        self.eval_sq(sqdist(x, y))
    }

    /// Assemble the (rows(x) × rows(y)) kernel matrix natively through
    /// the cache-blocked distance engine ([`crate::linalg::blocked`]):
    /// tiled r² via ‖x‖²+‖y‖²−2⟨x,y⟩ with precomputed row norms, then
    /// [`Kernel::eval_sq`] mapped per tile. Tile partitioning is
    /// shape-derived, so results are bit-identical for every thread
    /// count (they may differ from [`Kernel::matrix_scalar`] by r²
    /// cancellation round-off). The production path is the AOT/PJRT
    /// engine in `runtime`.
    pub fn matrix(&self, x: &Mat, y: &Mat) -> Mat {
        crate::linalg::blocked::map_matrix(x, y, |r2| self.eval_sq(r2))
    }

    /// [`Kernel::matrix`] with caller-precomputed row norms
    /// (`nx[i] = ‖x_i‖²`, `ny[j] = ‖y_j‖²`, exact
    /// [`crate::linalg::blocked::row_sqnorms`] values). Bitwise
    /// identical to [`Kernel::matrix`]; lets callers that assemble many
    /// blocks against one point set (the landmark Gram cache) pay the
    /// norms pass once instead of per call.
    pub fn matrix_pre(&self, x: &Mat, nx: &[f64], y: &Mat, ny: &[f64]) -> Mat {
        crate::linalg::blocked::map_matrix_pre(x, nx, y, ny, |r2| self.eval_sq(r2))
    }

    /// Symmetric kernel matrix K(X, X) — blocked engine, block-upper
    /// tiles only; the mirror is bitwise identical to direct evaluation
    /// (see [`crate::linalg::blocked`]).
    pub fn matrix_sym(&self, x: &Mat) -> Mat {
        crate::linalg::blocked::map_matrix_sym(x, |r2| self.eval_sq(r2))
    }

    /// The pre-blocked scalar reference: per-pair two-pass [`sqdist`],
    /// pool-parallel over row ranges. Kept as the oracle for
    /// blocked-vs-scalar validation and the `bench-perf` comparison —
    /// not a hot path.
    pub fn matrix_scalar(&self, x: &Mat, y: &Mat) -> Mat {
        assert_eq!(x.cols, y.cols, "dimension mismatch");
        let (n, m) = (x.rows, y.rows);
        let nt = if n * m * x.cols > 32 * 32 * 32 {
            crate::util::pool::current_threads()
        } else {
            1
        };
        let blocks = crate::util::pool::par_chunks_with(nt, n, |range| {
            let mut out = Vec::with_capacity(range.len() * m);
            for i in range {
                let xi = x.row(i);
                for j in 0..m {
                    out.push(self.eval_sq(sqdist(xi, y.row(j))));
                }
            }
            out
        });
        Mat { rows: n, cols: m, data: blocks.into_iter().flatten().collect() }
    }

    /// The kernel's spectral density m(‖s‖) as a function of the radial
    /// frequency, under the paper's normalization (App. A.1: C_α=D_α=1 for
    /// Matérn). For the Gaussian, m(r) = (2πσ²)^{d/2} e^{−2π²σ²r²}
    /// (Fourier pair of e^{−‖x‖²/2σ²} under the e^{−2πi⟨x,s⟩} convention).
    pub fn spectral_density(&self, r: f64, d: usize) -> f64 {
        match self.spec {
            KernelSpec::Matern { nu, .. } => {
                let alpha = nu + d as f64 / 2.0;
                (1.0 + r * r).powf(-alpha)
            }
            KernelSpec::Gaussian { sigma } => {
                let c = (2.0 * std::f64::consts::PI * sigma * sigma).powf(d as f64 / 2.0);
                c * (-2.0 * std::f64::consts::PI.powi(2) * sigma * sigma * r * r).exp()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-300)
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            KernelSpec::parse("matern:nu=0.5,a=2").unwrap(),
            KernelSpec::Matern { nu: 0.5, a: 2.0 }
        );
        assert_eq!(
            KernelSpec::parse("gaussian:sigma=0.25").unwrap(),
            KernelSpec::Gaussian { sigma: 0.25 }
        );
        assert!(KernelSpec::parse("rbf").is_err());
    }

    #[test]
    fn matern_closed_forms_match_bessel_path() {
        // The half-integer fast paths must agree with the general-ν Bessel
        // evaluation (same ν, evaluated by nudging ν off the fast path).
        for &nu in &[0.5, 1.5, 2.5] {
            let fast = Kernel::new(KernelSpec::Matern { nu, a: 1.3 });
            let slow = Kernel::new(KernelSpec::Matern { nu: nu + 1e-9, a: 1.3 });
            for &r2 in &[0.01, 0.25, 1.0, 4.0, 16.0] {
                let f = fast.eval_sq(r2);
                let s = slow.eval_sq(r2);
                assert!(rel(f, s) < 1e-5, "nu={nu} r2={r2}: {f} vs {s}");
            }
        }
    }

    #[test]
    fn kernels_are_one_at_zero_and_decreasing() {
        let mut rng = Rng::seed_from_u64(1);
        for spec in [
            KernelSpec::Matern { nu: 0.5, a: 1.0 },
            KernelSpec::Matern { nu: 1.5, a: 0.7 },
            KernelSpec::Matern { nu: 2.5, a: 2.0 },
            KernelSpec::Matern { nu: 1.1, a: 1.0 },
            KernelSpec::Gaussian { sigma: 0.8 },
        ] {
            let k = Kernel::new(spec);
            assert!(rel(k.eval_sq(0.0), 1.0) < 1e-9, "{spec:?} at 0");
            let mut prev = 1.0;
            for i in 1..40 {
                let r = i as f64 * 0.25;
                let v = k.eval_sq(r * r);
                assert!(v <= prev + 1e-12, "{spec:?} not decreasing at r={r}");
                assert!(v >= 0.0);
                prev = v;
            }
            // random symmetry checks
            for _ in 0..20 {
                let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                assert!(rel(k.eval(&x, &y), k.eval(&y, &x)) < 1e-14);
            }
        }
    }

    #[test]
    fn kernel_matrix_psd() {
        // K(X,X)+εI must be Cholesky-factorizable (PSD check).
        let mut rng = Rng::seed_from_u64(21);
        let x = Mat::from_fn(40, 3, |_, _| rng.normal());
        for spec in [
            KernelSpec::Matern { nu: 1.5, a: 1.0 },
            KernelSpec::Gaussian { sigma: 1.0 },
        ] {
            let k = Kernel::new(spec);
            let mut km = k.matrix_sym(&x);
            km.add_diag(1e-9);
            assert!(crate::linalg::Cholesky::factor(&km).is_ok(), "{spec:?}");
        }
    }

    #[test]
    fn matrix_sym_matches_matrix() {
        let mut rng = Rng::seed_from_u64(22);
        let x = Mat::from_fn(33, 4, |_, _| rng.normal());
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let a = k.matrix(&x, &x);
        let b = k.matrix_sym(&x);
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn blocked_matrix_matches_scalar_reference() {
        // The blocked engine may shift values by r² cancellation error;
        // for unit-scale data that is ≪ 1e-9 on the kernel values.
        let mut rng = Rng::seed_from_u64(23);
        for &(n, m, d) in &[(37usize, 21usize, 3usize), (150, 140, 5), (2, 1, 1)] {
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let y = Mat::from_fn(m, d, |_, _| rng.normal());
            for spec in [
                KernelSpec::Matern { nu: 1.5, a: 1.0 },
                KernelSpec::Gaussian { sigma: 0.8 },
            ] {
                let k = Kernel::new(spec);
                let blocked = k.matrix(&x, &y);
                let scalar = k.matrix_scalar(&x, &y);
                assert!(
                    blocked.max_abs_diff(&scalar) < 1e-9,
                    "{spec:?} ({n},{m},{d}): {}",
                    blocked.max_abs_diff(&scalar)
                );
            }
        }
    }

    #[test]
    fn spectral_density_matern_shape() {
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let d = 3;
        // m(0) = 1, decreasing, tail ~ r^{-2α}
        assert!(rel(k.spectral_density(0.0, d), 1.0) < 1e-12);
        let alpha: f64 = 1.5 + 1.5;
        let big: f64 = 1e4;
        assert!(
            rel(k.spectral_density(big, d), big.powf(-2.0 * alpha)) < 1e-3,
            "tail exponent"
        );
    }

    #[test]
    fn spectral_density_gaussian_integrates_to_k0() {
        // ∫ m(s) ds over R^d = K(0) = 1 (inverse FT at 0). Radially:
        // ∫_0^∞ m(r) ω_{d-1} r^{d-1} dr = 1.
        for d in [1usize, 2, 3] {
            let k = Kernel::new(KernelSpec::Gaussian { sigma: 0.7 });
            let omega = crate::special::sphere_surface(d);
            let got = crate::quadrature::integrate_semi_infinite(
                |r| k.spectral_density(r, d) * omega * r.powi(d as i32 - 1),
                1e-12,
            );
            assert!(rel(got, 1.0) < 1e-6, "d={d}: {got}");
        }
    }
}
