//! Stationary kernels and their spectral densities.
//!
//! The kernel zoo (all isotropic, k(0) = 1):
//!
//! | spec | k(r) | spectral density m(‖s‖) | SA integration |
//! |------|------|--------------------------|----------------|
//! | `Matern{nu,a}` | 2^{1−ν}/Γ(ν)·(ar)^ν K_ν(ar) | C_m(a²+4π²r²)^{−α}, α=ν+d/2 | closed form |
//! | `Laplacian{gamma}` | e^{−γr} (≡ Matérn ν=½, a=γ) | C_m(γ²+4π²r²)^{−(d+1)/2} | closed form |
//! | `Gaussian{sigma}` | e^{−r²/(2σ²)} | (2πσ²)^{d/2} e^{−2π²σ²r²} | polylog closed form |
//! | `RationalQuadratic{alpha,ell}` | (1+r²/(2αℓ²))^{−α} | c·t^ν K_ν(t), t=2πℓ√(2α)·r, ν=α−d/2 | quadrature |
//!
//! All densities are in the e^{−2πi⟨x,s⟩} Fourier convention, with the
//! kernels' *true* spectral constants (not the paper's C_α = D_α = 1
//! simplification of App. A.1), so ∫_{R^d} m(‖s‖) ds = k(0) = 1 exactly
//! and the SA values overlay the true leverage curve G in Figure 2.
//! [`SpectralDensity`] carries the precomputed constants; half-integer
//! ν uses closed forms for both k and t^ν K_ν(t), general ν falls back
//! to the Bessel integral in [`crate::special`]. The rational-quadratic
//! density follows from its Gamma(α, 1/(2αℓ²)) scale-mixture-of-Gaussians
//! representation and requires α > d/2.
//!
//! The native assembly functions here are the *fallback / oracle* path;
//! the production path assembles kernel blocks through the AOT-compiled
//! Pallas artifacts (see [`crate::runtime`]) and is validated against
//! these to 1e-5.

use crate::linalg::{sqdist, Mat};
use crate::special::{bessel_k, lgamma};
use std::f64::consts::PI;

/// Serializable kernel description (config-level).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelSpec {
    /// Matérn with smoothness ν and inverse length-scale a (K(r)=C_ν(a r)).
    Matern { nu: f64, a: f64 },
    /// Gaussian exp(−r²/(2σ²)).
    Gaussian { sigma: f64 },
    /// Laplacian (exponential) exp(−γr) — the Matérn ν=½ kernel with a=γ,
    /// kept as a first-class spec so configs can name it directly.
    Laplacian { gamma: f64 },
    /// Rational-quadratic (1 + r²/(2αℓ²))^{−α}: a Gamma-mixture of
    /// Gaussians over inverse squared length-scales; α→∞ recovers the
    /// Gaussian with σ=ℓ.
    RationalQuadratic { alpha: f64, ell: f64 },
}

/// The accepted CLI/config spellings, with their parameters and defaults.
pub const SUPPORTED_KERNELS: &[&str] = &[
    "matern:nu=1.5,a=1.0",
    "matern12:a=1.0",
    "matern32:a=1.0",
    "matern52:a=1.0",
    "laplacian:gamma=1.0",
    "gaussian:sigma=1.0",
    "rq:alpha=2.0,ell=1.0",
];

/// Typed error from [`KernelSpec::parse`]. The `Display` form of
/// [`KernelParseError::UnknownKernel`] lists every supported spelling so
/// a CLI typo is self-correcting.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelParseError {
    /// The kernel name isn't one of the supported spellings.
    UnknownKernel { name: String },
    /// A parameter clause failed to split as `k=v` or its value failed to
    /// parse as a float.
    BadParam { param: String, detail: String },
    /// A parameter name this kernel doesn't accept.
    UnknownParam { kernel: &'static str, param: String, accepts: &'static str },
    /// A parameter value outside the kernel's valid domain.
    InvalidValue { kernel: &'static str, param: &'static str, value: f64, expect: &'static str },
}

impl std::fmt::Display for KernelParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelParseError::UnknownKernel { name } => {
                write!(f, "unknown kernel '{name}'; supported: {}", SUPPORTED_KERNELS.join(" | "))
            }
            KernelParseError::BadParam { param, detail } => {
                write!(f, "bad kernel param '{param}': {detail}")
            }
            KernelParseError::UnknownParam { kernel, param, accepts } => {
                write!(f, "kernel '{kernel}' has no param '{param}' (accepts: {accepts})")
            }
            KernelParseError::InvalidValue { kernel, param, value, expect } => {
                write!(f, "kernel '{kernel}': {param}={value} invalid (expected {expect})")
            }
        }
    }
}

impl std::error::Error for KernelParseError {}

/// Check a parsed parameter map against a kernel's accepted names, then
/// fetch one value (falling back to its default) and require it finite
/// and strictly positive — every zoo parameter is a scale or smoothness.
fn take_param(
    kernel: &'static str,
    accepts: &'static [&'static str],
    accepts_str: &'static str,
    kv: &std::collections::BTreeMap<String, f64>,
    param: &'static str,
    default: f64,
) -> Result<f64, KernelParseError> {
    for k in kv.keys() {
        if !accepts.contains(&k.as_str()) {
            return Err(KernelParseError::UnknownParam {
                kernel,
                param: k.clone(),
                accepts: accepts_str,
            });
        }
    }
    let v = *kv.get(param).unwrap_or(&default);
    if !v.is_finite() || v <= 0.0 {
        return Err(KernelParseError::InvalidValue {
            kernel,
            param,
            value: v,
            expect: "a finite value > 0",
        });
    }
    Ok(v)
}

impl KernelSpec {
    /// Parse `"matern:nu=1.5,a=1.0"` / `"gaussian:sigma=0.5"` /
    /// `"matern32:a=2"` / `"laplacian:gamma=1"` / `"rq:alpha=2,ell=0.5"`
    /// CLI syntax. Unknown names, unknown params, and non-positive or
    /// non-finite values are typed [`KernelParseError`]s.
    pub fn parse(s: &str) -> Result<KernelSpec, KernelParseError> {
        let (name, rest) = s.split_once(':').unwrap_or((s, ""));
        let mut kv = std::collections::BTreeMap::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part.split_once('=').ok_or_else(|| KernelParseError::BadParam {
                param: part.trim().to_string(),
                detail: "expected k=v".to_string(),
            })?;
            let val = v.trim().parse::<f64>().map_err(|e| KernelParseError::BadParam {
                param: part.trim().to_string(),
                detail: e.to_string(),
            })?;
            kv.insert(k.trim().to_string(), val);
        }
        match name.trim() {
            "matern" => Ok(KernelSpec::Matern {
                nu: take_param("matern", &["nu", "a"], "nu, a", &kv, "nu", 1.5)?,
                a: take_param("matern", &["nu", "a"], "nu, a", &kv, "a", 1.0)?,
            }),
            fixed @ ("matern12" | "matern32" | "matern52") => Ok(KernelSpec::Matern {
                nu: match fixed {
                    "matern12" => 0.5,
                    "matern32" => 1.5,
                    _ => 2.5,
                },
                a: take_param(fixed, &["a"], "a", &kv, "a", 1.0)?,
            }),
            "laplacian" | "laplace" => Ok(KernelSpec::Laplacian {
                gamma: take_param("laplacian", &["gamma"], "gamma", &kv, "gamma", 1.0)?,
            }),
            "gaussian" => Ok(KernelSpec::Gaussian {
                sigma: take_param("gaussian", &["sigma"], "sigma", &kv, "sigma", 1.0)?,
            }),
            "rq" | "rational-quadratic" => Ok(KernelSpec::RationalQuadratic {
                alpha: take_param("rq", &["alpha", "ell"], "alpha, ell", &kv, "alpha", 2.0)?,
                ell: take_param("rq", &["alpha", "ell"], "alpha, ell", &kv, "ell", 1.0)?,
            }),
            other => Err(KernelParseError::UnknownKernel { name: other.to_string() }),
        }
    }

    pub fn build(self) -> Kernel {
        Kernel::new(self)
    }

    /// α = ν + d/2, the Sobolev smoothness of the Matérn RKHS (paper §3.1).
    pub fn alpha(&self, d: usize) -> f64 {
        match self {
            KernelSpec::Matern { nu, .. } => nu + d as f64 / 2.0,
            KernelSpec::Laplacian { .. } => 0.5 + d as f64 / 2.0,
            // Gaussian / RQ: C^∞ kernels with super-polynomial spectral
            // decay — no finite Sobolev order. The paper (App. C.2)
            // treats these via an "equivalent α"; callers that feed α
            // into λ rules cap it (e.g. `.min(20.0)` in the tuner).
            KernelSpec::Gaussian { .. } | KernelSpec::RationalQuadratic { .. } => f64::INFINITY,
        }
    }

    pub fn name(&self) -> String {
        match self {
            KernelSpec::Matern { nu, a } => format!("matern(nu={nu},a={a})"),
            KernelSpec::Gaussian { sigma } => format!("gaussian(sigma={sigma})"),
            KernelSpec::Laplacian { gamma } => format!("laplacian(gamma={gamma})"),
            KernelSpec::RationalQuadratic { alpha, ell } => {
                format!("rq(alpha={alpha},ell={ell})")
            }
        }
    }
}

/// A concrete kernel with fast evaluation paths.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub spec: KernelSpec,
    /// Precomputed 2^{1−ν}/Γ(ν) for the general-ν Matérn path.
    matern_norm: f64,
}

impl Kernel {
    pub fn new(spec: KernelSpec) -> Kernel {
        let matern_norm = match spec {
            KernelSpec::Matern { nu, .. } => {
                ((1.0 - nu) * std::f64::consts::LN_2 - lgamma(nu)).exp()
            }
            _ => 0.0,
        };
        Kernel { spec, matern_norm }
    }

    /// k(x, y) from the squared distance r² (all kernels are isotropic, so
    /// assembly only ever computes r² — this avoids n·m sqrt calls for the
    /// Gaussian and lets the Pallas kernel share the distance Gram).
    #[inline]
    pub fn eval_sq(&self, r2: f64) -> f64 {
        match self.spec {
            KernelSpec::Matern { nu, a } => {
                let r = r2.max(0.0).sqrt();
                let t = a * r;
                if t <= 1e-12 {
                    return 1.0;
                }
                // Half-integer closed forms (ν = ½, 3⁄2, 5⁄2) — the cases the
                // paper's experiments use and the Pallas kernels implement.
                if (nu - 0.5).abs() < 1e-12 {
                    (-t).exp()
                } else if (nu - 1.5).abs() < 1e-12 {
                    (1.0 + t) * (-t).exp()
                } else if (nu - 2.5).abs() < 1e-12 {
                    (1.0 + t + t * t / 3.0) * (-t).exp()
                } else {
                    // general ν: 2^{1−ν}/Γ(ν) t^ν K_ν(t)
                    self.matern_norm * t.powf(nu) * bessel_k(nu, t)
                }
            }
            // Same operation sequence as the Matérn ν=½ arm so the two
            // spellings are *bitwise* identical (pinned by test).
            KernelSpec::Laplacian { gamma } => {
                let r = r2.max(0.0).sqrt();
                let t = gamma * r;
                if t <= 1e-12 {
                    return 1.0;
                }
                (-t).exp()
            }
            KernelSpec::Gaussian { sigma } => (-r2 / (2.0 * sigma * sigma)).exp(),
            KernelSpec::RationalQuadratic { alpha, ell } => {
                (1.0 + r2.max(0.0) / (2.0 * alpha * ell * ell)).powf(-alpha)
            }
        }
    }

    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        self.eval_sq(sqdist(x, y))
    }

    /// Assemble the (rows(x) × rows(y)) kernel matrix natively through
    /// the cache-blocked distance engine ([`crate::linalg::blocked`]):
    /// tiled r² via ‖x‖²+‖y‖²−2⟨x,y⟩ with precomputed row norms, then
    /// [`Kernel::eval_sq`] mapped per tile. Tile partitioning is
    /// shape-derived, so results are bit-identical for every thread
    /// count (they may differ from [`Kernel::matrix_scalar`] by r²
    /// cancellation round-off). The production path is the AOT/PJRT
    /// engine in `runtime`.
    pub fn matrix(&self, x: &Mat, y: &Mat) -> Mat {
        crate::linalg::blocked::map_matrix(x, y, |r2| self.eval_sq(r2))
    }

    /// [`Kernel::matrix`] with caller-precomputed row norms
    /// (`nx[i] = ‖x_i‖²`, `ny[j] = ‖y_j‖²`, exact
    /// [`crate::linalg::blocked::row_sqnorms`] values). Bitwise
    /// identical to [`Kernel::matrix`]; lets callers that assemble many
    /// blocks against one point set (the landmark Gram cache) pay the
    /// norms pass once instead of per call.
    pub fn matrix_pre(&self, x: &Mat, nx: &[f64], y: &Mat, ny: &[f64]) -> Mat {
        crate::linalg::blocked::map_matrix_pre(x, nx, y, ny, |r2| self.eval_sq(r2))
    }

    /// Symmetric kernel matrix K(X, X) — blocked engine, block-upper
    /// tiles only; the mirror is bitwise identical to direct evaluation
    /// (see [`crate::linalg::blocked`]).
    pub fn matrix_sym(&self, x: &Mat) -> Mat {
        crate::linalg::blocked::map_matrix_sym(x, |r2| self.eval_sq(r2))
    }

    /// The pre-blocked scalar reference: per-pair two-pass [`sqdist`],
    /// pool-parallel over row ranges. Kept as the oracle for
    /// blocked-vs-scalar validation and the `bench-perf` comparison —
    /// not a hot path.
    pub fn matrix_scalar(&self, x: &Mat, y: &Mat) -> Mat {
        assert_eq!(x.cols, y.cols, "dimension mismatch");
        let (n, m) = (x.rows, y.rows);
        let nt = if n * m * x.cols > 32 * 32 * 32 {
            crate::util::pool::current_threads()
        } else {
            1
        };
        let blocks = crate::util::pool::par_chunks_with(nt, n, |range| {
            let mut out = Vec::with_capacity(range.len() * m);
            for i in range {
                let xi = x.row(i);
                for j in 0..m {
                    out.push(self.eval_sq(sqdist(xi, y.row(j))));
                }
            }
            out
        });
        Mat { rows: n, cols: m, data: blocks.into_iter().flatten().collect() }
    }

    /// The kernel's exact spectral density m(‖s‖) at radial frequency
    /// `r` in dimension `d` (e^{−2πi⟨x,s⟩} convention, ∫ m = k(0) = 1).
    /// Convenience wrapper over [`SpectralDensity`]; hot callers build
    /// the [`SpectralDensity`] once and reuse it.
    pub fn spectral_density(&self, r: f64, d: usize) -> f64 {
        SpectralDensity::new(self, d).eval(r)
    }
}

/// t^ν K_ν(t) with half-integer closed forms (exact at every t, no
/// Bessel quadrature) and the general-ν fallback through
/// [`crate::special::bessel_k`]. As t→0⁺ this tends to 2^{ν−1}Γ(ν).
fn t_pow_nu_knu(nu: f64, t: f64) -> f64 {
    let h = (PI / 2.0).sqrt();
    if (nu - 0.5).abs() < 1e-12 {
        h * (-t).exp()
    } else if (nu - 1.5).abs() < 1e-12 {
        h * (-t).exp() * (t + 1.0)
    } else if (nu - 2.5).abs() < 1e-12 {
        h * (-t).exp() * (t * t + 3.0 * t + 3.0)
    } else {
        t.powf(nu) * bessel_k(nu, t)
    }
}

/// True spectral-density description m(r) = c_m·g(r) for the kernel zoo,
/// in the e^{−2πi⟨x,s⟩} Fourier convention (∫_{R^d} m = K(0) = 1), with
/// every constant precomputed at construction:
///
/// * Matérn / Laplacian: m(r) = C_m (a² + 4π²r²)^{−α}, α = ν + d/2,
///   C_m = 2^d π^{d/2} Γ(α) a^{2ν} / Γ(ν) (Laplacian is ν=½, a=γ).
/// * Gaussian: m(r) = (2πσ²)^{d/2} e^{−2π²σ²r²}.
/// * Rational-quadratic: by the Gamma(α, 1/(2αℓ²)) scale-mixture
///   representation, m(r) = c·t^ν K_ν(t) with t = 2πℓ√(2α)·r,
///   ν = α − d/2 (**requires α > d/2**), and
///   c = 2^{1−ν} π^{d/2} (2αℓ²)^{d/2} / Γ(α).
pub struct SpectralDensity {
    pub d: usize,
    pub spec: KernelSpec,
    /// Matérn/Laplacian: C_m with m(r) = C_m (a² + 4π²r²)^{−α}.
    pub matern_cm: f64,
    /// Power-law Sobolev exponent; ∞ for the Gaussian / RQ.
    pub alpha: f64,
    /// RQ amplitude c in m(r) = c·t^ν K_ν(t).
    pub rq_cm: f64,
    /// RQ Bessel order ν = α − d/2.
    pub rq_nu: f64,
    /// RQ frequency scale: t = rq_as·r, rq_as = 2πℓ√(2α).
    pub rq_as: f64,
    /// m(0) — finite for every kernel in the zoo.
    pub m0: f64,
}

impl SpectralDensity {
    pub fn new(kernel: &Kernel, d: usize) -> Self {
        let df = d as f64;
        let mut sd = SpectralDensity {
            d,
            spec: kernel.spec,
            matern_cm: 0.0,
            alpha: f64::INFINITY,
            rq_cm: 0.0,
            rq_nu: 0.0,
            rq_as: 0.0,
            m0: 0.0,
        };
        match kernel.spec {
            KernelSpec::Matern { nu, a } => {
                let alpha = nu + df / 2.0;
                // C_m = 2^d π^{d/2} Γ(α) a^{2ν} / Γ(ν)
                let ln_cm = df * std::f64::consts::LN_2 + (df / 2.0) * PI.ln() + lgamma(alpha)
                    + 2.0 * nu * a.ln()
                    - lgamma(nu);
                sd.matern_cm = ln_cm.exp();
                sd.alpha = alpha;
                sd.m0 = sd.matern_cm * (a * a).powf(-alpha);
            }
            KernelSpec::Laplacian { gamma } => {
                // Matérn ν = ½ with a = γ: C_m = 2^d π^{d/2} Γ(α) γ / Γ(½)
                let nu = 0.5;
                let alpha = nu + df / 2.0;
                let ln_cm = df * std::f64::consts::LN_2 + (df / 2.0) * PI.ln() + lgamma(alpha)
                    + 2.0 * nu * gamma.ln()
                    - lgamma(nu);
                sd.matern_cm = ln_cm.exp();
                sd.alpha = alpha;
                sd.m0 = sd.matern_cm * (gamma * gamma).powf(-alpha);
            }
            KernelSpec::Gaussian { sigma } => {
                sd.m0 = (2.0 * PI * sigma * sigma).powf(df / 2.0);
            }
            KernelSpec::RationalQuadratic { alpha, ell } => {
                let nu = alpha - df / 2.0;
                assert!(
                    nu > 0.0,
                    "rational-quadratic spectral density needs alpha > d/2 \
                     (got alpha={alpha}, d={d})"
                );
                // c = 2^{1−ν} π^{d/2} (2αℓ²)^{d/2} / Γ(α)
                let ln_cm = (1.0 - nu) * std::f64::consts::LN_2 + (df / 2.0) * PI.ln()
                    + (df / 2.0) * (2.0 * alpha).ln()
                    + df * ell.ln()
                    - lgamma(alpha);
                sd.rq_cm = ln_cm.exp();
                sd.rq_nu = nu;
                sd.rq_as = 2.0 * PI * ell * (2.0 * alpha).sqrt();
                // lim_{t→0} t^ν K_ν(t) = 2^{ν−1} Γ(ν)
                sd.m0 = (ln_cm + (nu - 1.0) * std::f64::consts::LN_2 + lgamma(nu)).exp();
            }
        }
        sd
    }

    /// m(r) at radial frequency r.
    pub fn eval(&self, r: f64) -> f64 {
        match self.spec {
            KernelSpec::Matern { a, .. } => {
                self.matern_cm * (a * a + 4.0 * PI * PI * r * r).powf(-self.alpha)
            }
            KernelSpec::Laplacian { gamma } => {
                self.matern_cm * (gamma * gamma + 4.0 * PI * PI * r * r).powf(-self.alpha)
            }
            KernelSpec::Gaussian { sigma } => {
                (2.0 * PI * sigma * sigma).powf(self.d as f64 / 2.0)
                    * (-2.0 * PI * PI * sigma * sigma * r * r).exp()
            }
            KernelSpec::RationalQuadratic { .. } => {
                let t = self.rq_as * r;
                if t <= 1e-8 {
                    self.m0
                } else {
                    self.rq_cm * t_pow_nu_knu(self.rq_nu, t)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quadrature::integrate_semi_infinite;
    use crate::special::sphere_surface;
    use crate::util::rng::Rng;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-300)
    }

    /// One instance of every zoo member, unit-ish scales.
    fn zoo() -> Vec<KernelSpec> {
        vec![
            KernelSpec::Matern { nu: 0.5, a: 1.0 },
            KernelSpec::Matern { nu: 1.5, a: 0.7 },
            KernelSpec::Matern { nu: 2.5, a: 2.0 },
            KernelSpec::Matern { nu: 1.1, a: 1.0 },
            KernelSpec::Laplacian { gamma: 1.3 },
            KernelSpec::Gaussian { sigma: 0.8 },
            KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.6 },
        ]
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            KernelSpec::parse("matern:nu=0.5,a=2").unwrap(),
            KernelSpec::Matern { nu: 0.5, a: 2.0 }
        );
        assert_eq!(
            KernelSpec::parse("gaussian:sigma=0.25").unwrap(),
            KernelSpec::Gaussian { sigma: 0.25 }
        );
        assert!(KernelSpec::parse("rbf").is_err());
    }

    #[test]
    fn parse_accepts_every_supported_spelling() {
        for (s, want) in [
            ("matern", KernelSpec::Matern { nu: 1.5, a: 1.0 }),
            ("matern:nu=2.5,a=0.5", KernelSpec::Matern { nu: 2.5, a: 0.5 }),
            ("matern12", KernelSpec::Matern { nu: 0.5, a: 1.0 }),
            ("matern12:a=2", KernelSpec::Matern { nu: 0.5, a: 2.0 }),
            ("matern32:a=1.7", KernelSpec::Matern { nu: 1.5, a: 1.7 }),
            ("matern52", KernelSpec::Matern { nu: 2.5, a: 1.0 }),
            ("laplacian", KernelSpec::Laplacian { gamma: 1.0 }),
            ("laplacian:gamma=0.4", KernelSpec::Laplacian { gamma: 0.4 }),
            ("laplace:gamma=2", KernelSpec::Laplacian { gamma: 2.0 }),
            ("gaussian", KernelSpec::Gaussian { sigma: 1.0 }),
            ("rq", KernelSpec::RationalQuadratic { alpha: 2.0, ell: 1.0 }),
            ("rq:alpha=3,ell=0.5", KernelSpec::RationalQuadratic { alpha: 3.0, ell: 0.5 }),
            (
                "rational-quadratic:ell=0.3",
                KernelSpec::RationalQuadratic { alpha: 2.0, ell: 0.3 },
            ),
        ] {
            assert_eq!(KernelSpec::parse(s), Ok(want), "{s}");
        }
        // every SUPPORTED_KERNELS listing parses back to itself
        for s in SUPPORTED_KERNELS {
            assert!(KernelSpec::parse(s).is_ok(), "{s}");
        }
    }

    #[test]
    fn parse_rejects_bad_spellings_with_typed_errors() {
        // unknown kernel names list the supported set
        for s in ["rbf", "", "exp", "matern15"] {
            match KernelSpec::parse(s) {
                Err(KernelParseError::UnknownKernel { name }) => {
                    let msg = KernelParseError::UnknownKernel { name }.to_string();
                    assert!(msg.contains("laplacian"), "{msg}");
                    assert!(msg.contains("rq"), "{msg}");
                }
                other => panic!("{s}: expected UnknownKernel, got {other:?}"),
            }
        }
        // malformed / unparseable params
        assert!(matches!(
            KernelSpec::parse("matern:nu"),
            Err(KernelParseError::BadParam { .. })
        ));
        assert!(matches!(
            KernelSpec::parse("matern:nu=abc"),
            Err(KernelParseError::BadParam { .. })
        ));
        // params the kernel doesn't accept
        assert!(matches!(
            KernelSpec::parse("gaussian:nu=1"),
            Err(KernelParseError::UnknownParam { .. })
        ));
        assert!(matches!(
            KernelSpec::parse("matern12:nu=1.5"),
            Err(KernelParseError::UnknownParam { .. })
        ));
        assert!(matches!(
            KernelSpec::parse("laplacian:sigma=1"),
            Err(KernelParseError::UnknownParam { .. })
        ));
        // out-of-domain values
        for s in ["gaussian:sigma=0", "gaussian:sigma=-1", "matern:nu=0", "rq:alpha=0",
                  "laplacian:gamma=-2", "gaussian:sigma=nan"] {
            assert!(
                matches!(KernelSpec::parse(s), Err(KernelParseError::InvalidValue { .. })),
                "{s}: {:?}",
                KernelSpec::parse(s)
            );
        }
    }

    #[test]
    fn matern_closed_forms_match_bessel_path() {
        // The half-integer fast paths must agree with the general-ν Bessel
        // evaluation (same ν, evaluated by nudging ν off the fast path).
        for &nu in &[0.5, 1.5, 2.5] {
            let fast = Kernel::new(KernelSpec::Matern { nu, a: 1.3 });
            let slow = Kernel::new(KernelSpec::Matern { nu: nu + 1e-9, a: 1.3 });
            for &r2 in &[0.01, 0.25, 1.0, 4.0, 16.0] {
                let f = fast.eval_sq(r2);
                let s = slow.eval_sq(r2);
                assert!(rel(f, s) < 1e-5, "nu={nu} r2={r2}: {f} vs {s}");
            }
        }
    }

    #[test]
    fn laplacian_is_bitwise_matern_half() {
        // Same operation sequence ⇒ exactly equal, not just close — the
        // parity suites rely on spelling not mattering.
        let gamma = 1.7;
        let lap = Kernel::new(KernelSpec::Laplacian { gamma });
        let mat = Kernel::new(KernelSpec::Matern { nu: 0.5, a: gamma });
        for &r2 in &[0.0, 1e-30, 0.01, 0.25, 1.0, 4.0, 16.0, 900.0] {
            assert_eq!(
                lap.eval_sq(r2).to_bits(),
                mat.eval_sq(r2).to_bits(),
                "r2={r2}"
            );
        }
        // and their spectral densities agree (same constants)
        let sd_l = SpectralDensity::new(&lap, 3);
        let sd_m = SpectralDensity::new(&mat, 3);
        for &r in &[0.0, 0.1, 1.0, 10.0] {
            assert!(rel(sd_l.eval(r), sd_m.eval(r)) < 1e-14, "r={r}");
        }
    }

    #[test]
    fn rq_limits_to_gaussian_at_large_alpha() {
        // (1 + r²/(2αℓ²))^{−α} → e^{−r²/(2ℓ²)} as α→∞.
        let ell = 0.7;
        let rq = Kernel::new(KernelSpec::RationalQuadratic { alpha: 5e4, ell });
        let ga = Kernel::new(KernelSpec::Gaussian { sigma: ell });
        for &r2 in &[0.01, 0.25, 1.0, 4.0] {
            assert!(rel(rq.eval_sq(r2), ga.eval_sq(r2)) < 1e-3, "r2={r2}");
        }
    }

    #[test]
    fn kernels_are_one_at_zero_and_decreasing() {
        let mut rng = Rng::seed_from_u64(1);
        for spec in zoo() {
            let k = Kernel::new(spec);
            assert!(rel(k.eval_sq(0.0), 1.0) < 1e-9, "{spec:?} at 0");
            let mut prev = 1.0;
            for i in 1..40 {
                let r = i as f64 * 0.25;
                let v = k.eval_sq(r * r);
                assert!(v <= prev + 1e-12, "{spec:?} not decreasing at r={r}");
                assert!(v >= 0.0);
                prev = v;
            }
            // random symmetry checks
            for _ in 0..20 {
                let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                assert!(rel(k.eval(&x, &y), k.eval(&y, &x)) < 1e-14);
            }
        }
    }

    #[test]
    fn kernel_matrix_psd() {
        // K(X,X)+εI must be Cholesky-factorizable (PSD check) for every
        // zoo member — stationarity + positive spectral density ⇒ PSD.
        let mut rng = Rng::seed_from_u64(21);
        let x = Mat::from_fn(40, 3, |_, _| rng.normal());
        for spec in zoo() {
            let k = Kernel::new(spec);
            let mut km = k.matrix_sym(&x);
            km.add_diag(1e-9);
            assert!(crate::linalg::Cholesky::factor(&km).is_ok(), "{spec:?}");
        }
    }

    #[test]
    fn matrix_sym_matches_matrix() {
        let mut rng = Rng::seed_from_u64(22);
        let x = Mat::from_fn(33, 4, |_, _| rng.normal());
        for spec in [
            KernelSpec::Matern { nu: 1.5, a: 1.0 },
            KernelSpec::Laplacian { gamma: 1.0 },
            KernelSpec::RationalQuadratic { alpha: 2.0, ell: 0.8 },
        ] {
            let k = Kernel::new(spec);
            let a = k.matrix(&x, &x);
            let b = k.matrix_sym(&x);
            assert!(a.max_abs_diff(&b) < 1e-14, "{spec:?}");
        }
    }

    #[test]
    fn blocked_matrix_matches_scalar_reference() {
        // The blocked engine may shift values by r² cancellation error;
        // for unit-scale data that is ≪ 1e-9 on the kernel values.
        let mut rng = Rng::seed_from_u64(23);
        for &(n, m, d) in &[(37usize, 21usize, 3usize), (150, 140, 5), (2, 1, 1)] {
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let y = Mat::from_fn(m, d, |_, _| rng.normal());
            for spec in zoo() {
                let k = Kernel::new(spec);
                let blocked = k.matrix(&x, &y);
                let scalar = k.matrix_scalar(&x, &y);
                assert!(
                    blocked.max_abs_diff(&scalar) < 1e-9,
                    "{spec:?} ({n},{m},{d}): {}",
                    blocked.max_abs_diff(&scalar)
                );
            }
        }
    }

    #[test]
    fn spectral_density_matern_shape() {
        // Exact constants: m(0) = C_m·a^{−2α}, tail m(r) ≈ C_m(4π²)^{−α}r^{−2α}.
        let k = Kernel::new(KernelSpec::Matern { nu: 1.5, a: 1.0 });
        let d = 3;
        let sd = SpectralDensity::new(&k, d);
        let alpha: f64 = 1.5 + 1.5;
        assert!(rel(k.spectral_density(0.0, d), sd.m0) < 1e-12);
        assert!(rel(sd.m0, sd.matern_cm) < 1e-12, "a=1 ⇒ m(0)=C_m");
        let big: f64 = 1e4;
        let tail = sd.matern_cm * (4.0 * PI * PI).powf(-alpha) * big.powf(-2.0 * alpha);
        assert!(rel(k.spectral_density(big, d), tail) < 1e-3, "tail exponent");
    }

    #[test]
    fn spectral_density_zoo_integrates_to_k0() {
        // ∫ m(s) ds over R^d = K(0) = 1 (inverse FT at 0). Radially:
        // ∫_0^∞ m(r) ω_{d-1} r^{d-1} dr = 1. Pins every zoo member's
        // spectral constants (the RQ needs α > d/2).
        for spec in [
            KernelSpec::Matern { nu: 1.5, a: 1.3 },
            KernelSpec::Matern { nu: 2.5, a: 0.8 },
            KernelSpec::Laplacian { gamma: 1.4 },
            KernelSpec::Gaussian { sigma: 0.7 },
            KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.6 },
            KernelSpec::RationalQuadratic { alpha: 4.0, ell: 1.1 },
        ] {
            for d in [1usize, 2, 3] {
                let k = Kernel::new(spec);
                let sd = SpectralDensity::new(&k, d);
                let omega = sphere_surface(d);
                let got = integrate_semi_infinite(
                    |r| sd.eval(r) * omega * r.powi(d as i32 - 1),
                    1e-12,
                );
                assert!(rel(got, 1.0) < 1e-5, "{spec:?} d={d}: ∫m = {got}");
            }
        }
    }

    #[test]
    fn spectral_density_tails_have_correct_decay() {
        // Matérn/Laplacian: polynomial r^{−2α}. RQ: exponential with rate
        // rq_as — t^ν K_ν(t) ~ √(π/2)·t^{ν−1/2}e^{−t} for large t.
        let d = 2;
        let lap = Kernel::new(KernelSpec::Laplacian { gamma: 1.0 });
        let sdl = SpectralDensity::new(&lap, d);
        let (r1, r2) = (50.0, 100.0);
        let slope = (sdl.eval(r2) / sdl.eval(r1)).ln() / (r2 / r1).ln();
        assert!((slope - (-2.0 * sdl.alpha)).abs() < 0.01, "laplacian slope {slope}");

        let rq = Kernel::new(KernelSpec::RationalQuadratic { alpha: 2.5, ell: 0.5 });
        let sdr = SpectralDensity::new(&rq, d);
        for &r in &[1.0, 2.0, 4.0] {
            let t = sdr.rq_as * r;
            let asym = sdr.rq_cm * (PI / 2.0).sqrt() * t.powf(sdr.rq_nu - 0.5) * (-t).exp();
            assert!(rel(sdr.eval(r), asym) < 0.2, "rq r={r}: {} vs {asym}", sdr.eval(r));
        }
    }

    #[test]
    fn rq_spectral_density_matches_kernel_by_inverse_transform_1d() {
        // 1-d check of the scale-mixture constants:
        // k(u) = 2∫₀^∞ m(r) cos(2πru) dr.
        let k = Kernel::new(KernelSpec::RationalQuadratic { alpha: 2.0, ell: 0.8 });
        let sd = SpectralDensity::new(&k, 1);
        for &u in &[0.1, 0.5, 1.0, 2.0] {
            let got = integrate_semi_infinite(
                |r| 2.0 * sd.eval(r) * (2.0 * PI * r * u).cos(),
                1e-11,
            );
            let want = k.eval_sq(u * u);
            assert!(rel(got, want) < 1e-4, "u={u}: {got} vs {want}");
        }
    }

    #[test]
    fn spectral_density_gaussian_integrates_to_k0() {
        // ∫ m(s) ds over R^d = K(0) = 1 (inverse FT at 0). Radially:
        // ∫_0^∞ m(r) ω_{d-1} r^{d-1} dr = 1.
        for d in [1usize, 2, 3] {
            let k = Kernel::new(KernelSpec::Gaussian { sigma: 0.7 });
            let omega = sphere_surface(d);
            let got = crate::quadrature::integrate_semi_infinite(
                |r| k.spectral_density(r, d) * omega * r.powi(d as i32 - 1),
                1e-12,
            );
            assert!(rel(got, 1.0) < 1e-6, "d={d}: {got}");
        }
    }
}
