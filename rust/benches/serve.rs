//! `cargo bench --bench serve -- [--full] [--reps k] [--seed s]`
//! HTTP serving tier: sustained QPS + p50/p95/p99 latency vs batcher
//! max_batch and replica count; writes machine-readable
//! `BENCH_serve.json`.
//! See `leverkrr::bench_harness::experiments::serve` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli(
        "serve",
        "HTTP serving tier throughput/latency experiment driver",
    );
    leverkrr::bench_harness::experiments::serve::run(&opts);
}
