//! `cargo bench --bench fig2 -- [--full] [--reps N] [--ns a,b,c] [--out f.json]`
//! Regenerates the paper's fig2 experiment. See
//! `leverkrr::bench_harness::experiments::fig2` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("fig2", "paper experiment driver");
    leverkrr::bench_harness::experiments::fig2::run(&opts);
}
