//! `cargo bench --bench fig1 -- [--full] [--reps N] [--ns a,b,c] [--out f.json]`
//! Regenerates the paper's fig1 experiment. See
//! `leverkrr::bench_harness::experiments::fig1` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("fig1", "paper experiment driver");
    leverkrr::bench_harness::experiments::fig1::run(&opts);
}
