//! `cargo bench --bench fig3 -- [--full] [--reps N] [--ns a,b,c] [--out f.json]`
//! Regenerates the paper's fig3 experiment. See
//! `leverkrr::bench_harness::experiments::fig3` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("fig3", "paper experiment driver");
    leverkrr::bench_harness::experiments::fig3::run(&opts);
}
