//! `cargo bench --bench persist -- [--full] [--ns a,b,c] [--reps k]`
//! Artifact save/load and stream checkpoint/restore latency vs n, m;
//! writes machine-readable `BENCH_persist.json`.
//! See `leverkrr::bench_harness::experiments::persist` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli(
        "persist",
        "persistence (save/load/restore) experiment driver",
    );
    leverkrr::bench_harness::experiments::persist::run(&opts);
}
