//! `cargo bench --bench ablation -- [--full] [--reps N]`
//! SA design-choice ablations (integration path, KDE backend, LOO,
//! stabilization). See `leverkrr::bench_harness::experiments::ablation`.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("ablation", "SA ablations");
    leverkrr::bench_harness::experiments::ablation::run(&opts);
}
