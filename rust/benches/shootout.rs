//! `cargo bench --bench shootout -- [--full] [--tune] [--kernels ..] [--dists ..] [--out f.json]`
//! Leverage-backend shootout: time-to-equal-prediction-accuracy for
//! exact/SA/RC/BLESS across the kernel zoo × input-distribution grid. See
//! `leverkrr::bench_harness::experiments::shootout` for the protocol.
fn main() {
    let opts = leverkrr::bench_harness::experiments::shootout::ShootoutOptions::parse_cli();
    leverkrr::bench_harness::experiments::shootout::run(&opts);
}
