//! `cargo bench --bench stream -- [--full] [--ns a,b,c] [--out f.json]`
//! Streaming per-arrival latency + end-state risk vs periodic full refit.
//! See `leverkrr::bench_harness::experiments::stream` for the setting.
fn main() {
    let opts =
        leverkrr::bench_harness::ExpOptions::parse_cli("stream", "streaming experiment driver");
    leverkrr::bench_harness::experiments::stream::run(&opts);
}
