//! `cargo bench --bench perf -- [--full] [--reps N] [--ns a,b,c] [--out f.json]`
//! Regenerates the paper's perf experiment. See
//! `leverkrr::bench_harness::experiments::perf` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("perf", "paper experiment driver");
    leverkrr::bench_harness::experiments::perf::run(&opts);
}
