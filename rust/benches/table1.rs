//! `cargo bench --bench table1 -- [--full] [--reps N] [--ns a,b,c] [--out f.json]`
//! Regenerates the paper's table1 experiment. See
//! `leverkrr::bench_harness::experiments::table1` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("table1", "paper experiment driver");
    leverkrr::bench_harness::experiments::table1::run(&opts);
}
