//! `cargo bench --bench obs -- [--full] [--reps N]`
//! Measures span-tracer overhead on the fig1 pipeline (budget <2%).
//! See `leverkrr::bench_harness::experiments::obs` for the setting.
fn main() {
    let opts = leverkrr::bench_harness::ExpOptions::parse_cli("obs", "tracing overhead driver");
    leverkrr::bench_harness::experiments::obs::run(&opts);
}
