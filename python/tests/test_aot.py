"""AOT artifact pipeline: manifest integrity + determinism."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PY_DIR = os.path.join(REPO, "python")


def run_aot(out_dir, only=""):
    cmd = [sys.executable, "-m", "compile.aot", "--out-dir", out_dir]
    if only:
        cmd += ["--only", only]
    subprocess.run(cmd, cwd=PY_DIR, check=True, capture_output=True)


def test_aot_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path / "arts")
    run_aot(out, only="matern05_block,kde_block")
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert man["tm"] == 128 and man["tn"] == 128 and man["d"] == 8
    assert set(man["entries"]) == {"matern05_block", "kde_block"}
    for name, meta in man["entries"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule")
        assert len(text) == meta["bytes"]


def test_aot_is_deterministic(tmp_path):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    run_aot(a, only="gaussian_block")
    run_aot(b, only="gaussian_block")
    ja = json.load(open(os.path.join(a, "manifest.json")))
    jb = json.load(open(os.path.join(b, "manifest.json")))
    assert (
        ja["entries"]["gaussian_block"]["sha256_16"]
        == jb["entries"]["gaussian_block"]["sha256_16"]
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(REPO, "artifacts", "manifest.json")),
    reason="run `make artifacts` first",
)
def test_checked_in_artifacts_match_manifest():
    art = os.path.join(REPO, "artifacts")
    man = json.load(open(os.path.join(art, "manifest.json")))
    for name, meta in man["entries"].items():
        path = os.path.join(art, meta["file"])
        assert os.path.exists(path), f"{name} missing"
        assert len(open(path).read()) == meta["bytes"], f"{name} stale"
