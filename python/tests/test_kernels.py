"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps values, dimensionality (via zero-padding patterns),
scale parameters, and mask occupancy; every case asserts allclose at
float32 tolerances.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pairwise, ref
from compile.kernels.pairwise import D_MAX, TM, TN

RTOL = 2e-5
ATOL = 2e-5

KERNELS = ["matern05", "matern15", "matern25", "gaussian"]


def rand(shape, rng, scale=2.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@pytest.mark.parametrize("name", KERNELS)
def test_kernel_block_matches_ref(name):
    rng = np.random.default_rng(0)
    x = rand((TM, D_MAX), rng)
    y = rand((TN, D_MAX), rng)
    scale = jnp.asarray([1.3], dtype=jnp.float32)
    got = pairwise.kernel_block(name, x, y, scale)
    want = ref.kernel_block_ref(name, x, y, scale[0])
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("name", KERNELS)
def test_kernel_block_diagonal_is_one(name):
    # K(x, x) = 1 at distance zero. The ‖x‖²+‖y‖²−2⟨x,y⟩ expansion leaves
    # an O(1e-5) f32 cancellation residual on the diagonal; kernels that
    # are √-nonsmooth at 0 (Matérn ν=1/2, 3/2, 5/2 ~ exp(−a√r²)) amplify
    # it to O(3e-3). This is inherent to f32 tiles (the rust runtime's
    # parity test carries the same bound); smooth kernels stay at 1e-5.
    rng = np.random.default_rng(1)
    x = rand((TM, D_MAX), rng)
    scale = jnp.asarray([0.8], dtype=jnp.float32)
    got = np.asarray(pairwise.kernel_block(name, x, x, scale))
    atol = 1e-3 if name == "gaussian" else 5e-3
    np.testing.assert_allclose(np.diag(got), 1.0, atol=atol)
    # symmetric
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", KERNELS)
def test_zero_padding_is_inert(name):
    """Zero-padding the feature dimension must not change the block —
    the property the rust runtime relies on for d < D_MAX."""
    rng = np.random.default_rng(2)
    d_true = 3
    x_small = rng.standard_normal((TM, d_true), dtype=np.float32)
    y_small = rng.standard_normal((TN, d_true), dtype=np.float32)
    x_pad = np.zeros((TM, D_MAX), dtype=np.float32)
    y_pad = np.zeros((TN, D_MAX), dtype=np.float32)
    x_pad[:, :d_true] = x_small
    y_pad[:, :d_true] = y_small
    scale = jnp.asarray([1.0], dtype=jnp.float32)
    got = pairwise.kernel_block(name, jnp.asarray(x_pad), jnp.asarray(y_pad), scale)
    want = ref.kernel_block_ref(
        name, jnp.asarray(x_small), jnp.asarray(y_small), scale[0]
    )
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.05, 8.0),
    name=st.sampled_from(KERNELS),
    d_true=st.integers(1, D_MAX),
    spread=st.floats(0.01, 10.0),
)
def test_kernel_block_hypothesis(seed, scale, name, d_true, spread):
    """Property sweep: random values/scales/dims, Pallas == oracle."""
    rng = np.random.default_rng(seed)
    x = np.zeros((TM, D_MAX), dtype=np.float32)
    y = np.zeros((TN, D_MAX), dtype=np.float32)
    x[:, :d_true] = rng.standard_normal((TM, d_true)) * spread
    y[:, :d_true] = rng.standard_normal((TN, d_true)) * spread
    s = jnp.asarray([scale], dtype=jnp.float32)
    got = np.asarray(pairwise.kernel_block(name, jnp.asarray(x), jnp.asarray(y), s))
    want = np.asarray(ref.kernel_block_ref(name, jnp.asarray(x), jnp.asarray(y), s[0]))
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)
    # range invariant: kernels live in [0, 1]
    assert got.min() >= -1e-6 and got.max() <= 1.0 + 1e-5


def test_kde_block_matches_ref():
    rng = np.random.default_rng(3)
    q = rand((TM, D_MAX), rng, 0.7)
    data = rand((TN, D_MAX), rng, 0.7)
    w = jnp.asarray((rng.random(TN) < 0.8).astype(np.float32))
    h = jnp.asarray([0.35], dtype=jnp.float32)
    got = pairwise.kde_block(q, data, w, h)
    want = ref.kde_block_ref(q, data, w, h[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.floats(0.05, 3.0),
    occupancy=st.floats(0.0, 1.0),
)
def test_kde_block_hypothesis(seed, h, occupancy):
    """Mask occupancy sweep: padded rows must contribute exactly zero."""
    rng = np.random.default_rng(seed)
    q = rand((TM, D_MAX), rng, 0.5)
    data = rand((TN, D_MAX), rng, 0.5)
    n_real = max(1, int(TN * occupancy))
    w = np.zeros(TN, dtype=np.float32)
    w[:n_real] = 1.0
    hh = jnp.asarray([h], dtype=jnp.float32)
    got = np.asarray(pairwise.kde_block(q, data, jnp.asarray(w), hh))
    # oracle computed only over the real rows
    want = np.asarray(
        ref.kde_block_ref(q, data[:n_real], jnp.ones(n_real, jnp.float32), hh[0])
    )
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # KDE sums are bounded by the number of unmasked rows
    assert got.max() <= n_real + 1e-3
    assert got.min() >= 0.0


def test_sqdist_tile_nonnegative_and_zero_diag():
    rng = np.random.default_rng(4)
    x = rand((TM, D_MAX), rng, 5.0)
    d2 = np.asarray(pairwise._sqdist_tile(x, x))
    assert d2.min() >= 0.0
    np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-3)


def test_vmem_footprint_fits():
    """The DESIGN.md claim: one tile's working set ≪ 16 MiB VMEM."""
    assert pairwise.vmem_footprint_bytes() < 1 << 20  # < 1 MiB
