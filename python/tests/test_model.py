"""L2 correctness: entry-point shapes, fused predict block, AOT lowering."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.kernels.pairwise import D_MAX, TM, TN


def _rand_args(kind, seed=0, tm=TM, tn=TN):
    rng = np.random.default_rng(seed)
    f32 = np.float32
    x = jnp.asarray(rng.standard_normal((tm, D_MAX)).astype(f32))
    y = jnp.asarray(rng.standard_normal((tn, D_MAX)).astype(f32))
    v = jnp.asarray(rng.standard_normal(tn).astype(f32))
    s = jnp.asarray([0.9], dtype=f32)
    if kind == "kernel_block":
        return (x, y, s)
    if kind == "kde_block":
        return (x, y, jnp.abs(v) < 1.0, s)
    if kind == "predict_block":
        return (x, y, v, s)
    raise ValueError(kind)


@pytest.mark.parametrize("name", sorted(model.ENTRIES))
def test_entry_shapes(name):
    fn, kind, (tm, tn) = model.ENTRIES[name]
    args = _rand_args(kind, tm=tm, tn=tn)
    if kind == "kde_block":
        args = (args[0], args[1], args[2].astype(jnp.float32), args[3])
    out = fn(*args)
    assert isinstance(out, tuple) and len(out) == 1
    if kind == "kernel_block":
        assert out[0].shape == (tm, tn)
    else:
        assert out[0].shape == (tm,)
    assert out[0].dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(out[0])))


@pytest.mark.parametrize("kname", ["matern15", "gaussian"])
def test_predict_block_is_fused_kernel_matvec(kname):
    """predict_block must equal kernel_block @ beta exactly (same graph)."""
    fn, _, _tiles = model.ENTRIES[f"predict_{kname}"]
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((TM, D_MAX)).astype(np.float32))
    land = jnp.asarray(rng.standard_normal((TN, D_MAX)).astype(np.float32))
    beta = jnp.asarray(rng.standard_normal(TN).astype(np.float32))
    s = jnp.asarray([1.1], dtype=jnp.float32)
    got = fn(q, land, beta, s)[0]
    k = ref.kernel_block_ref(kname, q, land, s[0])
    want = k @ beta
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_predict_block_zero_beta_padding_masks():
    """β=0 on padded landmark rows ⇒ those rows cannot contribute."""
    fn, _, _tiles = model.ENTRIES["predict_matern15"]
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((TM, D_MAX)).astype(np.float32))
    land = jnp.asarray(rng.standard_normal((TN, D_MAX)).astype(np.float32))
    beta = np.zeros(TN, dtype=np.float32)
    beta[: TN // 2] = rng.standard_normal(TN // 2)
    s = jnp.asarray([1.0], dtype=jnp.float32)
    full = fn(q, land, jnp.asarray(beta), s)[0]
    # garbage in the padded landmark rows must not matter
    land2 = np.asarray(land).copy()
    land2[TN // 2 :] = 1e3
    got = fn(q, jnp.asarray(land2), jnp.asarray(beta), s)[0]
    np.testing.assert_allclose(got, full, rtol=1e-5, atol=1e-5)


def test_example_args_match_entry_kinds():
    for name, (_, kind, (tm, tn)) in model.ENTRIES.items():
        args = model.example_args(kind, tm, tn)
        assert all(a.dtype == jnp.float32 for a in args), name
        assert args[0].shape == (tm, D_MAX), name


@pytest.mark.parametrize("name", ["matern15_block", "kde_block"])
def test_aot_lowering_emits_hlo_text(name):
    """The full lowering path (jit → StableHLO → XlaComputation → HLO
    text) must succeed and produce a parseable-looking module."""
    from compile.aot import to_hlo_text

    fn, kind, (tm, tn) = model.ENTRIES[name]
    text = to_hlo_text(fn, model.example_args(kind, tm, tn))
    assert text.startswith("HloModule")
    assert "f32[128,8]" in text  # tile inputs present
    assert len(text) > 500


def test_lowered_module_roundtrips_numerically():
    """Execute the lowered HLO (via jax's own client) and compare to the
    eager entry — catches lowering bugs before the rust side ever runs."""
    fn, kind, _tiles = model.ENTRIES["matern15_block"]
    args = _rand_args(kind, seed=3)
    eager = fn(*args)[0]
    lowered = jax.jit(fn).lower(*args).compile()
    compiled = lowered(*args)[0]
    np.testing.assert_allclose(eager, compiled, rtol=1e-5, atol=1e-5)
