"""L2: JAX compute graphs lowered to the AOT artifacts.

Each entry point is a jittable function over fixed tile shapes that calls
the L1 Pallas kernels — the whole graph (Pallas body included, via
interpret=True) lowers to a single HLO module that the rust runtime
executes per tile. Python never runs at serve time.

Entry points (see aot.py for the lowering and the manifest):
  * kernel_block_<name>(x, y, scale) → (TM, TN) kernel matrix tile
  * kde_block(q, data, w, h)         → (TM,) masked KDE partial sums
  * predict_block(q, land, beta, scale) → (TM,) fused K(q, X_m)·β tile
    (serving fast path: avoids materializing the query kernel block on
    the host)
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise
from .kernels.pairwise import D_MAX, TM, TN, kde_block, kernel_block  # noqa: F401


def make_kernel_block(name):
    """Close over the kernel name → a jittable (x, y, scale) graph."""

    def fn(x, y, scale):
        return (kernel_block(name, x, y, scale),)

    fn.__name__ = f"kernel_block_{name}"
    return fn


def kde_block_entry(q, data, w, h):
    return (kde_block(q, data, w, h),)


def predict_block_entry_factory(name):
    """Fused Nyström predict tile: K(q, landmarks)·β.

    β for padded landmark rows is zero, so padding is self-masking.
    """

    def fn(q, land, beta, scale):
        k = kernel_block(name, q, land, scale)
        return (jnp.dot(k, beta, preferred_element_type=jnp.float32),)

    fn.__name__ = f"predict_block_{name}"
    return fn


#: Large-tile geometry (perf variant): one CPU-PJRT dispatch costs
#: ~100–300 µs, so big assemblies want fewer, fatter tiles. 512×512×8 f32
#: is 2 MiB of output + 32 KiB inputs — still far under the 16 MiB VMEM
#: budget on real TPU (EXPERIMENTS.md §Perf records the measured win).
TM_L = 512
TN_L = 512


def example_args(kind, tm=TM, tn=TN):
    """ShapeDtypeStructs for lowering each entry kind at a tile size."""
    f32 = jnp.float32
    tile_x = jax.ShapeDtypeStruct((tm, D_MAX), f32)
    tile_y = jax.ShapeDtypeStruct((tn, D_MAX), f32)
    scalar = jax.ShapeDtypeStruct((1,), f32)
    vec_n = jax.ShapeDtypeStruct((tn,), f32)
    if kind == "kernel_block":
        return (tile_x, tile_y, scalar)
    if kind == "kde_block":
        return (tile_x, tile_y, vec_n, scalar)
    if kind == "predict_block":
        return (tile_x, tile_y, vec_n, scalar)
    raise ValueError(kind)


#: name → (entry fn, kind, (tm, tn)); the manifest mirrors this table.
ENTRIES = {
    "matern05_block": (make_kernel_block("matern05"), "kernel_block", (TM, TN)),
    "matern15_block": (make_kernel_block("matern15"), "kernel_block", (TM, TN)),
    "matern25_block": (make_kernel_block("matern25"), "kernel_block", (TM, TN)),
    "gaussian_block": (make_kernel_block("gaussian"), "kernel_block", (TM, TN)),
    "kde_block": (kde_block_entry, "kde_block", (TM, TN)),
    "predict_matern05": (predict_block_entry_factory("matern05"), "predict_block", (TM, TN)),
    "predict_matern15": (predict_block_entry_factory("matern15"), "predict_block", (TM, TN)),
    "predict_matern25": (predict_block_entry_factory("matern25"), "predict_block", (TM, TN)),
    "predict_gaussian": (predict_block_entry_factory("gaussian"), "predict_block", (TM, TN)),
    # large-tile perf variants (runtime picks per problem size)
    "matern05_block_l": (make_kernel_block("matern05"), "kernel_block", (TM_L, TN_L)),
    "matern15_block_l": (make_kernel_block("matern15"), "kernel_block", (TM_L, TN_L)),
    "matern25_block_l": (make_kernel_block("matern25"), "kernel_block", (TM_L, TN_L)),
    "gaussian_block_l": (make_kernel_block("gaussian"), "kernel_block", (TM_L, TN_L)),
    "kde_block_l": (kde_block_entry, "kde_block", (TM_L, TN_L)),
}
