"""AOT lowering: L2 graphs (with L1 Pallas bodies) → HLO text artifacts.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes one `<entry>.hlo.txt` per ENTRIES item plus `manifest.json`
describing tile geometry — everything the rust runtime needs.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, args):
    """jit → lower → StableHLO → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default="", help="comma-separated entry names (default: all)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = {s for s in args.only.split(",") if s}

    manifest = {
        "version": 1,
        "tm": model.TM,
        "tn": model.TN,
        "d": model.D_MAX,
        "jax_version": jax.__version__,
        "entries": {},
    }
    for name, (fn, kind, (tm, tn)) in model.ENTRIES.items():
        if only and name not in only:
            continue
        text = to_hlo_text(fn, model.example_args(kind, tm, tn))
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["entries"][name] = {
            "file": fname,
            "kind": kind,
            "tm": tm,
            "tn": tn,
            "sha256_16": digest,
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars → {path}")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
