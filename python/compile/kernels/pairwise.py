"""L1 Pallas kernels: pairwise kernel-matrix blocks and masked KDE sums.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper's hot
spot is assembling dense kernel blocks K(X, Y) — an O(n·m·d) pairwise
computation a CUDA implementation would tile over threadblocks with the
distance Gram staged through shared memory. On TPU the same insight maps
to: tile (TM, D)×(TN, D) blocks into VMEM via BlockSpec, compute the
−2·X·Yᵀ contraction on the MXU (a rank-D matmul — `jnp.dot` inside the
kernel), add the row/col squared norms on the VPU, and apply the scalar
kernel profile elementwise. One fused Pallas kernel per tile keeps the
whole block resident in VMEM: 2·128·8·4B inputs + 128·128·4B output
≈ 74 KiB ≪ 16 MiB VMEM; a (128,8)@(8,128) MXU matmul per tile.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering in interpret mode emits plain HLO the rust runtime
executes. The same code compiles for real TPU by flipping the flag.

The scale parameter (Matérn `a` / Gaussian `σ` / KDE `h`) enters as a
(1,)-shaped operand so ONE artifact serves every hyperparameter setting —
no recompilation on the λ/bandwidth sweeps the benches run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry — shared with aot.py and the rust runtime via the
# manifest. 128 matches the MXU systolic dimension; D_MAX=8 covers the
# paper's experiments (d ≤ 8 after HTRU2) with zero-padding for d < 8.
TM = 128
TN = 128
D_MAX = 8


def _sqdist_tile(x, y):
    """(TM,D)·(TN,D) → (TM,TN) squared distances, MXU-friendly form."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    yy = jnp.sum(y * y, axis=1)[None, :]
    # the rank-D contraction — this is the MXU matmul on real hardware
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _matern05_profile(r2, a):
    return jnp.exp(-a * jnp.sqrt(r2))


def _matern15_profile(r2, a):
    t = a * jnp.sqrt(r2)
    return (1.0 + t) * jnp.exp(-t)


def _matern25_profile(r2, a):
    t = a * jnp.sqrt(r2)
    return (1.0 + t + t * t / 3.0) * jnp.exp(-t)


def _gaussian_profile(r2, sigma):
    return jnp.exp(-r2 / (2.0 * sigma * sigma))


PROFILES = {
    "matern05": _matern05_profile,
    "matern15": _matern15_profile,
    "matern25": _matern25_profile,
    "gaussian": _gaussian_profile,
}


def _kernel_block_kernel(profile, x_ref, y_ref, scale_ref, o_ref):
    """Pallas kernel body: one fused distance-Gram + profile tile."""
    x = x_ref[...]
    y = y_ref[...]
    a = scale_ref[0]
    o_ref[...] = profile(_sqdist_tile(x, y), a)


@functools.partial(jax.jit, static_argnames=("name",))
def kernel_block(name, x, y, scale):
    """K(x, y) tile for kernel `name`; x:(TM,D), y:(TN,D), scale:(1,)."""
    profile = PROFILES[name]
    return pl.pallas_call(
        functools.partial(_kernel_block_kernel, profile),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], y.shape[0]), jnp.float32),
        interpret=True,
    )(x, y, scale)


def _kde_block_kernel(q_ref, d_ref, w_ref, h_ref, o_ref):
    """Masked Gaussian-KDE partial sums over one data tile."""
    q = q_ref[...]
    x = d_ref[...]
    w = w_ref[...]
    h = h_ref[0]
    d2 = _sqdist_tile(q, x)
    k = jnp.exp(-d2 / (2.0 * h * h))
    # mask out padded data rows, reduce over the data axis (VPU reduce)
    o_ref[...] = jnp.dot(k, w, preferred_element_type=jnp.float32)


@jax.jit
def kde_block(q, data, w, h):
    """Partial KDE sums; q:(TM,D), data:(TN,D), w:(TN,), h:(1,) → (TM,)."""
    return pl.pallas_call(
        _kde_block_kernel,
        out_shape=jax.ShapeDtypeStruct((q.shape[0],), jnp.float32),
        interpret=True,
    )(q, data, w, h)


def vmem_footprint_bytes(tm=TM, tn=TN, d=D_MAX):
    """Estimated VMEM residency of one kernel-block tile (f32).

    Used by DESIGN.md / EXPERIMENTS.md to argue the real-TPU schedule:
    inputs + distance Gram + output, all f32.
    """
    inputs = (tm * d + tn * d + 1) * 4
    gram = tm * tn * 4
    output = tm * tn * 4
    return inputs + gram + output
