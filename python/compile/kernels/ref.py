"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact counterpart here written
with plain jax.numpy. pytest asserts allclose between the two across
shapes, dtypes, parameters, and padding patterns — this is the CORE
correctness signal for the L1 layer (the rust test suite then checks the
AOT artifacts against the *rust-native* implementation, closing the loop).
"""

import jax.numpy as jnp

__all__ = [
    "sqdist",
    "matern05",
    "matern15",
    "matern25",
    "gaussian",
    "kernel_block_ref",
    "kde_block_ref",
]


def sqdist(x, y):
    """Pairwise squared distances ‖x_i − y_j‖² for x:(m,d), y:(n,d).

    Uses the expansion ‖x‖² + ‖y‖² − 2⟨x,y⟩ — identical to the Pallas
    kernel so rounding behaviour matches (both clamp at 0).
    """
    xx = jnp.sum(x * x, axis=1)[:, None]
    yy = jnp.sum(y * y, axis=1)[None, :]
    d2 = xx + yy - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def matern05(r2, a):
    """Matérn ν=1/2 (exponential): exp(−a·r)."""
    r = jnp.sqrt(r2)
    return jnp.exp(-a * r)


def matern15(r2, a):
    """Matérn ν=3/2: (1 + a·r)·exp(−a·r)."""
    t = a * jnp.sqrt(r2)
    return (1.0 + t) * jnp.exp(-t)


def matern25(r2, a):
    """Matérn ν=5/2: (1 + a·r + (a·r)²/3)·exp(−a·r)."""
    t = a * jnp.sqrt(r2)
    return (1.0 + t + t * t / 3.0) * jnp.exp(-t)


def gaussian(r2, sigma):
    """Gaussian kernel exp(−r²/(2σ²))."""
    return jnp.exp(-r2 / (2.0 * sigma * sigma))


_KERNELS = {
    "matern05": matern05,
    "matern15": matern15,
    "matern25": matern25,
    "gaussian": gaussian,
}


def kernel_block_ref(name, x, y, scale):
    """Reference kernel block K(x, y):(m,n) for kernel `name`."""
    return _KERNELS[name](sqdist(x, y), scale)


def kde_block_ref(q, data, w, h):
    """Masked Gaussian-KDE partial sums.

    q:(m,d) queries, data:(n,d) points, w:(n,) 0/1 mask for padded rows,
    h: bandwidth. Returns (m,) with sum_j w_j·exp(−‖q_i−x_j‖²/(2h²)).
    (Normalization by n·(2πh²)^{d/2} happens on the rust side, which
    knows the true n and d before padding.)
    """
    d2 = sqdist(q, data)
    k = jnp.exp(-d2 / (2.0 * h * h))
    return k @ w
